//! GST-FDPA: group-scaled truncated fused dot-product-add
//! (paper Algorithm 9).
//!
//! Models the dedicated MXFP4/NVFP4 paths on Blackwell: exact fixed-point
//! dot products per group of `G` elements, each multiplied by the signed
//! significands of its block scale factors, then one truncated fused
//! summation of the `L/G` group terms plus the accumulator.

use super::special::{special_pattern, NanStyle, SpecialOut};
use super::{acc_term, product_term_bits, scan_specials, zero_result_negative, MAX_L};
use crate::fixedpoint::{e_max, FxTerm};
use crate::formats::{convert, Decoded, Format, Rho, RoundingMode};

/// Parameters of a GST-FDPA operation (paper Table 5 row).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GstFdpaCfg {
    /// Group size `G` for the exact per-group dot products.
    pub g: usize,
    /// Block size of the scale factors (`K_block`: 32 for MXFP4, 16 for NVFP4).
    pub kblock: usize,
    /// Fractional bits of the fused summation.
    pub f: i32,
    /// Output conversion.
    pub rho: Rho,
    /// Scale factor format (E8M0 for MXFP4, UE4M3 for NVFP4).
    pub scale_fmt: Format,
}

/// GST-FDPA over bit patterns.
///
/// `alpha`/`beta` hold one scale per `kblock` consecutive elements
/// (`len = ⌈L / kblock⌉`). A ragged `L` — the tail chunk of a `K` that is
/// not a multiple of the vector length — is allowed: the final group and
/// the final scale block may be partial.
pub fn gst_fdpa(
    in_fmt: Format,
    a: &[u64],
    b: &[u64],
    c_bits: u64,
    alpha: &[u64],
    beta: &[u64],
    cfg: GstFdpaCfg,
) -> u64 {
    let l = a.len();
    debug_assert_eq!(b.len(), l);
    // hard assert: stack staging below would index out of bounds otherwise
    assert!(l <= MAX_L, "FDPA vector length {l} exceeds {MAX_L}");
    debug_assert_eq!(alpha.len(), l.div_ceil(cfg.kblock));
    debug_assert_eq!(beta.len(), l.div_ceil(cfg.kblock));

    let out_fmt = cfg.rho.output_format();
    let c = out_fmt.decode(c_bits);
    let mut da = [Decoded::ZERO; MAX_L];
    let mut db = [Decoded::ZERO; MAX_L];
    for i in 0..l {
        da[i] = in_fmt.decode(a[i]);
        db[i] = in_fmt.decode(b[i]);
    }
    let (da, db) = (&da[..l], &db[..l]);
    let nblk = alpha.len();
    let mut salpha = [Decoded::ZERO; MAX_L];
    let mut sbeta = [Decoded::ZERO; MAX_L];
    for i in 0..nblk {
        salpha[i] = cfg.scale_fmt.decode(alpha[i]);
        sbeta[i] = cfg.scale_fmt.decode(beta[i]);
    }

    if salpha[..nblk].iter().chain(sbeta[..nblk].iter()).any(|s| s.is_nan()) {
        return special_pattern(SpecialOut::Nan, out_fmt, NanStyle::NvCanonical);
    }
    match scan_specials(da.iter().copied().zip(db.iter().copied()), c) {
        SpecialOut::None => {}
        s => return special_pattern(s, out_fmt, NanStyle::NvCanonical),
    }

    let fs = cfg.scale_fmt.mant_bits() as i32;
    let groups = l.div_ceil(cfg.g);
    // Fixed-size staging (≤ L/G group terms + accumulator); zero terms are
    // skipped — e_max and the aligned sum ignore them anyway.
    let mut terms = [FxTerm::ZERO; MAX_L + 1];
    let mut nterms = 0usize;
    // Per-group product staging, reused across groups (one LUT load per
    // lane; entries past the current group length are never read).
    let mut gterms = [FxTerm::ZERO; MAX_L];

    for g in 0..groups {
        let blk = g * cfg.g / cfg.kblock;
        let (sa, sb) = (salpha[blk], sbeta[blk]);
        // Step 1a: exact fixed-point dot product of the group at a common
        // LSB of 2^min_lsb. Product terms come from the pair-product LUT
        // (single loads for the ≤ 8-bit MX/NVFP4 element formats);
        // the LSB exponent of a term is `t.exp - t.frac`.
        let lo = g * cfg.g;
        let hi = (lo + cfg.g).min(l);
        let mut min_lsb = i32::MAX;
        for k in lo..hi {
            let t = product_term_bits(in_fmt, a[k], b[k], da[k], db[k]);
            if !t.is_zero() {
                min_lsb = min_lsb.min(t.exp - t.frac);
            }
            gterms[k - lo] = t;
        }
        if min_lsb == i32::MAX {
            continue;
        }
        let mut p: i128 = 0;
        for t in &gterms[..hi - lo] {
            if t.is_zero() {
                continue;
            }
            let v = (t.mag as i128) << ((t.exp - t.frac) - min_lsb);
            if t.neg {
                p -= v;
            } else {
                p += v;
            }
        }
        // Step 1b: multiply by the scale significands; nominal exponent of
        // the group term is the sum of the scale exponents only.
        let s_g = p * sa.sig as i128 * sb.sig as i128;
        let e_g = sa.exp + sb.exp;
        if s_g == 0 {
            continue;
        }
        // value = s_g * 2^(min_lsb - fs - fs) * 2^(e_g)
        terms[nterms] = FxTerm {
            neg: s_g < 0,
            mag: s_g.unsigned_abs(),
            exp: e_g,
            frac: 2 * fs - min_lsb,
        };
        nterms += 1;
    }
    terms[nterms] = acc_term(out_fmt, c);
    nterms += 1;
    let terms = &terms[..nterms];

    let emax = match e_max(terms) {
        Some(e) => e,
        None => {
            let neg = zero_result_negative(
                da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
                c.sign,
            );
            return if neg { 1u64 << (out_fmt.width() - 1) } else { 0 };
        }
    };

    // Step 2: truncated fused sum of L/G + 1 terms.
    let s: i128 = terms
        .iter()
        .map(|t| t.align(emax, cfg.f, RoundingMode::TowardZero))
        .sum();

    if s == 0 {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 1u64 << (out_fmt.width() - 1) } else { 0 };
    }
    // Step 3: convert.
    convert(cfg.rho, s, emax, cfg.f)
}

/// Monomorphized GST-FDPA core: the whole scale-block geometry —
/// vector length `L`, group size `G`, group count `GROUPS = L/G`, scale
/// block size `KBLOCK`, block count `NBLK = L/KBLOCK` — plus the
/// summation precision `F` folded as constants, so every stage runs as a
/// fixed-trip-count lane loop over exactly-sized stack arrays.
///
/// Bit-identical to [`gst_fdpa`] for whole (non-ragged) chunks: group
/// terms stay lane-indexed with zero slots instead of being compacted
/// (`e_max`/`align` skip zeros), and the accumulator term is summed first
/// instead of last (the aligned-quanta i128 adds are exact, hence
/// order-insensitive). Ragged chunks fall back to the interpreter.
#[inline(always)]
pub(crate) fn gst_fdpa_lanes<
    const L: usize,
    const G: usize,
    const GROUPS: usize,
    const KBLOCK: usize,
    const NBLK: usize,
    const F: i32,
>(
    in_fmt: Format,
    scale_fmt: Format,
    rho: Rho,
    a: &[u64],
    b: &[u64],
    c_bits: u64,
    alpha: &[u64],
    beta: &[u64],
) -> u64 {
    debug_assert_eq!(GROUPS * G, L);
    debug_assert_eq!(NBLK * KBLOCK, L);
    let a: &[u64; L] = a.try_into().expect("chunk length == L");
    let b: &[u64; L] = b.try_into().expect("chunk length == L");
    let alpha: &[u64; NBLK] = alpha.try_into().expect("scale block count == NBLK");
    let beta: &[u64; NBLK] = beta.try_into().expect("scale block count == NBLK");

    let out_fmt = rho.output_format();
    let c = out_fmt.decode(c_bits);
    let mut da = [Decoded::ZERO; L];
    let mut db = [Decoded::ZERO; L];
    for i in 0..L {
        da[i] = in_fmt.decode(a[i]);
    }
    for i in 0..L {
        db[i] = in_fmt.decode(b[i]);
    }
    let mut salpha = [Decoded::ZERO; NBLK];
    let mut sbeta = [Decoded::ZERO; NBLK];
    for i in 0..NBLK {
        salpha[i] = scale_fmt.decode(alpha[i]);
        sbeta[i] = scale_fmt.decode(beta[i]);
    }

    if salpha.iter().chain(sbeta.iter()).any(|s| s.is_nan()) {
        return special_pattern(SpecialOut::Nan, out_fmt, NanStyle::NvCanonical);
    }
    match scan_specials(da.iter().copied().zip(db.iter().copied()), c) {
        SpecialOut::None => {}
        s => return special_pattern(s, out_fmt, NanStyle::NvCanonical),
    }

    let fs = scale_fmt.mant_bits() as i32;
    // Group terms stay lane-indexed; all-zero groups leave a zero slot.
    let mut terms = [FxTerm::ZERO; GROUPS];
    for g in 0..GROUPS {
        let blk = g * G / KBLOCK;
        let (sa, sb) = (salpha[blk], sbeta[blk]);
        // Step 1a: exact fixed-point dot product of the group at a common
        // LSB of 2^min_lsb.
        let lo = g * G;
        let mut gterms = [FxTerm::ZERO; G];
        let mut min_lsb = i32::MAX;
        for i in 0..G {
            let t = product_term_bits(in_fmt, a[lo + i], b[lo + i], da[lo + i], db[lo + i]);
            if !t.is_zero() {
                min_lsb = min_lsb.min(t.exp - t.frac);
            }
            gterms[i] = t;
        }
        if min_lsb == i32::MAX {
            continue;
        }
        let mut p: i128 = 0;
        for t in &gterms {
            if t.is_zero() {
                continue;
            }
            let v = (t.mag as i128) << ((t.exp - t.frac) - min_lsb);
            if t.neg {
                p -= v;
            } else {
                p += v;
            }
        }
        // Step 1b: multiply by the scale significands; nominal exponent of
        // the group term is the sum of the scale exponents only.
        let s_g = p * sa.sig as i128 * sb.sig as i128;
        let e_g = sa.exp + sb.exp;
        if s_g == 0 {
            continue;
        }
        terms[g] = FxTerm {
            neg: s_g < 0,
            mag: s_g.unsigned_abs(),
            exp: e_g,
            frac: 2 * fs - min_lsb,
        };
    }
    let cterm = acc_term(out_fmt, c);

    let mut emax: Option<i32> = None;
    for t in terms.iter().chain(std::iter::once(&cterm)) {
        if !t.is_zero() {
            emax = Some(match emax {
                Some(e) => e.max(t.exp),
                None => t.exp,
            });
        }
    }
    let emax = match emax {
        Some(e) => e,
        None => {
            let neg = zero_result_negative(
                da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
                c.sign,
            );
            return if neg { 1u64 << (out_fmt.width() - 1) } else { 0 };
        }
    };

    // Step 2: truncated fused sum of L/G + 1 terms.
    let mut s: i128 = cterm.align(emax, F, RoundingMode::TowardZero);
    for t in &terms {
        s += t.align(emax, F, RoundingMode::TowardZero);
    }

    if s == 0 {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 1u64 << (out_fmt.width() - 1) } else { 0 };
    }
    // Step 3: convert.
    convert(rho, s, emax, F)
}

#[cfg(test)]
mod tests {
    use super::*;

    const NVFP4: GstFdpaCfg = GstFdpaCfg {
        g: 16,
        kblock: 16,
        f: 35,
        rho: Rho::RzFp32,
        scale_fmt: Format::Ue4M3,
    };
    const MXFP4: GstFdpaCfg = GstFdpaCfg {
        g: 16,
        kblock: 32,
        f: 35,
        rho: Rho::RzFp32,
        scale_fmt: Format::E8M0,
    };

    fn fp4(v: f64) -> u64 {
        Format::Fp4E2M1.from_f64(v)
    }

    #[test]
    fn nvfp4_simple_dot() {
        // 64 elements, 4 blocks of 16, unit scales (UE4M3 1.0 = 0x38)
        let a: Vec<u64> = (0..64).map(|i| fp4(if i % 2 == 0 { 1.0 } else { -0.5 })).collect();
        let b: Vec<u64> = (0..64).map(|_| fp4(2.0)).collect();
        let scales = vec![0x38u64; 4];
        let c = Format::Fp32.from_f64(0.5);
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, c, &scales, &scales, NVFP4);
        // 32*(2.0) + 32*(-1.0) + 0.5 = 32.5
        assert_eq!(f32::from_bits(out as u32), 32.5);
    }

    #[test]
    fn group_dot_is_exact_before_truncation() {
        // Within a group, tiny and huge elements sum exactly (no F-truncation
        // inside the group dot product).
        let mut a = vec![fp4(0.0); 64];
        let mut b = vec![fp4(0.0); 64];
        a[0] = fp4(6.0);
        b[0] = fp4(6.0);
        a[1] = fp4(0.5);
        b[1] = fp4(0.5);
        let scales = vec![0x38u64; 4];
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, 0, &scales, &scales, NVFP4);
        assert_eq!(f32::from_bits(out as u32), 36.25);
    }

    #[test]
    fn ue4m3_scale_significand_multiplies() {
        // NVFP4 scale 1.5*2^2 = 6.0 (UE4M3 0x4C): dot * 6 * 1
        let mut a = vec![fp4(0.0); 16];
        let mut b = vec![fp4(0.0); 16];
        a[0] = fp4(2.0);
        b[0] = fp4(3.0);
        let alpha = [Format::Ue4M3.from_f64(6.0)];
        let beta = [0x38u64];
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, 0, &alpha, &beta, NVFP4);
        assert_eq!(f32::from_bits(out as u32), 36.0);
    }

    #[test]
    fn mxfp4_kblock32_shares_scale_across_two_groups() {
        // L=64, G=16, Kblock=32: groups 0,1 share scale[0]; 2,3 share scale[1]
        let mut a = vec![fp4(0.0); 64];
        let mut b = vec![fp4(0.0); 64];
        a[0] = fp4(1.0);
        b[0] = fp4(1.0); // group 0
        a[31] = fp4(1.0);
        b[31] = fp4(1.0); // group 1 (same block)
        a[32] = fp4(1.0);
        b[32] = fp4(1.0); // group 2 (block 1)
        let alpha = [129u64, 127u64]; // 2^2, 2^0
        let beta = [127u64, 127u64];
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, 0, &alpha, &beta, MXFP4);
        assert_eq!(f32::from_bits(out as u32), 4.0 + 4.0 + 1.0);
    }

    #[test]
    fn truncation_across_groups_at_f35() {
        // group terms 2^4 and 2^-33 (scale exps +4, -33): relative shift 37 > 35
        let mut a = vec![fp4(0.0); 32];
        let mut b = vec![fp4(0.0); 32];
        a[0] = fp4(1.0);
        b[0] = fp4(1.0);
        a[16] = fp4(1.0);
        b[16] = fp4(1.0);
        let alpha = [127u64 + 4, 127u64 - 37];
        let beta = [127u64, 127u64];
        let cfg = GstFdpaCfg { kblock: 16, ..MXFP4 };
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, 0, &alpha, &beta, cfg);
        assert_eq!(f32::from_bits(out as u32), 16.0, "2^-37-scaled group truncated");
        // at shift 34 it survives
        let alpha = [127u64 + 4, 127u64 - 30];
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, 0, &alpha, &beta, cfg);
        assert_eq!(f32::from_bits(out as u32), 16.0 + 2f32.powi(-30));
    }

    #[test]
    fn ragged_tail_chunk_with_partial_scale_block() {
        // The tail chunk of a ragged K (e.g. L = 8 left over from K = 40
        // with a 32-wide vector): one partial group and one partial scale
        // block, which must still be consumed and applied.
        let mut a = vec![fp4(0.0); 8];
        let mut b = vec![fp4(0.0); 8];
        a[6] = fp4(1.0);
        b[6] = fp4(1.0);
        let alpha = [129u64]; // 2^2
        let beta = [127u64]; // 2^0
        let cfg = GstFdpaCfg { kblock: 16, ..MXFP4 };
        let c = Format::Fp32.from_f64(0.25);
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, c, &alpha, &beta, cfg);
        assert_eq!(f32::from_bits(out as u32), 4.25, "partial block scale applied");
    }

    #[test]
    fn nan_scale_is_canonical_nan() {
        let a = vec![fp4(1.0); 16];
        let b = vec![fp4(1.0); 16];
        let out = gst_fdpa(Format::Fp4E2M1, &a, &b, 0, &[0x7F], &[0x38], NVFP4);
        assert_eq!(out, 0x7FFF_FFFF);
    }
}
