//! TR-FDPA: truncated rounded fused dot-product-add (paper Algorithm 10).
//!
//! Models TF32/BF16/FP16 MFMA instructions on AMD CDNA3. Unlike T-FDPA,
//! the fused summation covers only the `L` products; the accumulator is
//! added afterwards in a two-term *rounded* sum using the asymmetric
//! round-down (RD) mode — the source of the paper's §6.2.4 numerical bias.
//! Products may overflow to ±∞ when `|s_k·2^{e_k}| ≥ 2^128` (§4.2).

use super::special::{special_pattern, NanStyle, SpecialOut};
use super::{acc_term, product_term_bits, scan_specials, zero_result_negative, MAX_L};
use crate::fixedpoint::{e_max, FxTerm};
use crate::formats::{convert, Decoded, Format, Rho, RoundingMode};

/// Parameters of a TR-FDPA operation (paper Table 7 row).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrFdpaCfg {
    /// Fractional bits of the product fused summation (and of `s'_c`).
    pub f: i32,
    /// Fractional bits of the rounded product-sum term `T'`.
    pub f2: i32,
    /// Rounding mode of the internal two-term sum (RD on CDNA3; the
    /// hypothetical RZ variant of Figure 3 swaps this).
    pub inner_mode: RoundingMode,
}

impl TrFdpaCfg {
    /// CDNA3 production configuration (Table 7).
    pub const fn cdna3() -> Self {
        TrFdpaCfg { f: 24, f2: 31, inner_mode: RoundingMode::Down }
    }
}

/// Does the exact product of two finite decoded values overflow 2^128?
#[inline]
fn product_overflows(t: &FxTerm) -> bool {
    if t.is_zero() {
        return false;
    }
    // value = mag * 2^(exp - frac) ; overflow iff value >= 2^128
    let msb = 127 - t.mag.leading_zeros() as i32;
    (t.exp - t.frac) + msb >= 128
}

/// TR-FDPA over bit patterns. `c` is FP32; output is FP32 (ρ = RNE-FP32).
pub fn tr_fdpa(in_fmt: Format, a: &[u64], b: &[u64], c_bits: u64, cfg: TrFdpaCfg) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let l = a.len();
    // hard assert: stack staging below would index out of bounds otherwise
    assert!(l <= MAX_L, "FDPA vector length {l} exceeds {MAX_L}");
    let c = Format::Fp32.decode(c_bits);
    // fixed-size decode staging: no heap allocation on the hot path
    let mut da = [Decoded::ZERO; MAX_L];
    let mut db = [Decoded::ZERO; MAX_L];
    for i in 0..l {
        da[i] = in_fmt.decode(a[i]);
        db[i] = in_fmt.decode(b[i]);
    }
    let (da, db) = (&da[..l], &db[..l]);

    // Step 1: exact products (one LUT load per lane for ≤ 8-bit inputs);
    // detect multiplication overflow to ±∞.
    let mut terms = [FxTerm::ZERO; MAX_L];
    let mut nterms = 0usize;
    let mut ovf_pos = false;
    let mut ovf_neg = false;
    for i in 0..l {
        let t = product_term_bits(in_fmt, a[i], b[i], da[i], db[i]);
        if product_overflows(&t) {
            if t.neg {
                ovf_neg = true;
            } else {
                ovf_pos = true;
            }
            continue;
        }
        terms[nterms] = t;
        nterms += 1;
    }
    let terms = &terms[..nterms];

    let mut special = scan_specials(da.iter().copied().zip(db.iter().copied()), c);
    // merge multiplication overflows into the special outcome
    if ovf_pos || ovf_neg {
        special = match special {
            SpecialOut::Nan => SpecialOut::Nan,
            SpecialOut::Inf(neg) => {
                if (neg && ovf_pos) || (!neg && ovf_neg) || (ovf_pos && ovf_neg) {
                    SpecialOut::Nan
                } else {
                    SpecialOut::Inf(neg)
                }
            }
            SpecialOut::None => {
                if ovf_pos && ovf_neg {
                    SpecialOut::Nan
                } else {
                    SpecialOut::Inf(ovf_neg)
                }
            }
        };
    }
    match special {
        SpecialOut::None => {}
        s => return special_pattern(s, Format::Fp32, NanStyle::Quiet),
    }

    // Step 2: truncated fused sum of the L products (c NOT included).
    let emax_p = e_max(&terms);
    let t_sum: i128 = match emax_p {
        Some(e) => terms.iter().map(|t| t.align(e, cfg.f, RoundingMode::TowardZero)).sum(),
        None => 0,
    };

    // Step 3: rounded two-term sum of T and c at E = max(e_max, e_c).
    let cterm = acc_term(Format::Fp32, c);
    let e_p = emax_p.unwrap_or(i32::MIN / 2);
    let e_c = if cterm.is_zero() { i32::MIN / 2 } else { cterm.exp };
    if t_sum == 0 && cterm.is_zero() {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 0x8000_0000 } else { 0 };
    }
    let e = e_p.max(e_c);

    // T' = RD_F2(T * 2^(e_max - E)) : T is in quanta 2^(e_max - F).
    let t_prime = if t_sum == 0 {
        0i128
    } else {
        crate::formats::signed_align(
            t_sum < 0,
            t_sum.unsigned_abs(),
            e_p - cfg.f,
            e,
            cfg.f2,
            cfg.inner_mode,
        )
    };
    // s'_c = RD_F(c aligned at E), then widened to F2 quanta.
    let s_c = if cterm.is_zero() {
        0i128
    } else {
        cterm.align(e, cfg.f, cfg.inner_mode) << (cfg.f2 - cfg.f)
    };
    let s = t_prime + s_c;

    if s == 0 {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 0x8000_0000 } else { 0 };
    }
    // Step 4: ρ = RNE-FP32.
    convert(Rho::RneFp32, s, e, cfg.f2)
}

/// Monomorphized TR-FDPA core: `L`, `F`, `F2` folded as constants so the
/// decode gathers and product construction are fixed-width lane loops.
///
/// Bit-identical to [`tr_fdpa`]: overflowed products are recorded in the
/// flags and *zeroed in place* instead of being compacted out — sound
/// because [`e_max`] skips zero terms and a zero term aligns to 0 quanta,
/// so the truncated sum is unchanged.
#[inline(always)]
pub(crate) fn tr_fdpa_lanes<const L: usize, const F: i32, const F2: i32>(
    in_fmt: Format,
    inner_mode: RoundingMode,
    a: &[u64],
    b: &[u64],
    c_bits: u64,
) -> u64 {
    let a: &[u64; L] = a.try_into().expect("chunk length == L");
    let b: &[u64; L] = b.try_into().expect("chunk length == L");
    let c = Format::Fp32.decode(c_bits);
    let mut da = [Decoded::ZERO; L];
    let mut db = [Decoded::ZERO; L];
    for i in 0..L {
        da[i] = in_fmt.decode(a[i]);
    }
    for i in 0..L {
        db[i] = in_fmt.decode(b[i]);
    }

    // Step 1: exact products; detect multiplication overflow to ±∞.
    let mut terms = [FxTerm::ZERO; L];
    let mut ovf_pos = false;
    let mut ovf_neg = false;
    for i in 0..L {
        let t = product_term_bits(in_fmt, a[i], b[i], da[i], db[i]);
        if product_overflows(&t) {
            if t.neg {
                ovf_neg = true;
            } else {
                ovf_pos = true;
            }
            continue; // slot stays FxTerm::ZERO
        }
        terms[i] = t;
    }

    let mut special = scan_specials(da.iter().copied().zip(db.iter().copied()), c);
    // merge multiplication overflows into the special outcome
    if ovf_pos || ovf_neg {
        special = match special {
            SpecialOut::Nan => SpecialOut::Nan,
            SpecialOut::Inf(neg) => {
                if (neg && ovf_pos) || (!neg && ovf_neg) || (ovf_pos && ovf_neg) {
                    SpecialOut::Nan
                } else {
                    SpecialOut::Inf(neg)
                }
            }
            SpecialOut::None => {
                if ovf_pos && ovf_neg {
                    SpecialOut::Nan
                } else {
                    SpecialOut::Inf(ovf_neg)
                }
            }
        };
    }
    match special {
        SpecialOut::None => {}
        s => return special_pattern(s, Format::Fp32, NanStyle::Quiet),
    }

    // Step 2: truncated fused sum of the L products (c NOT included).
    let emax_p = e_max(&terms);
    let t_sum: i128 = match emax_p {
        Some(e) => terms.iter().map(|t| t.align(e, F, RoundingMode::TowardZero)).sum(),
        None => 0,
    };

    // Step 3: rounded two-term sum of T and c at E = max(e_max, e_c).
    let cterm = acc_term(Format::Fp32, c);
    let e_p = emax_p.unwrap_or(i32::MIN / 2);
    let e_c = if cterm.is_zero() { i32::MIN / 2 } else { cterm.exp };
    if t_sum == 0 && cterm.is_zero() {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 0x8000_0000 } else { 0 };
    }
    let e = e_p.max(e_c);

    let t_prime = if t_sum == 0 {
        0i128
    } else {
        crate::formats::signed_align(t_sum < 0, t_sum.unsigned_abs(), e_p - F, e, F2, inner_mode)
    };
    let s_c = if cterm.is_zero() {
        0i128
    } else {
        cterm.align(e, F, inner_mode) << (F2 - F)
    };
    let s = t_prime + s_c;

    if s == 0 {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 0x8000_0000 } else { 0 };
    }
    // Step 4: ρ = RNE-FP32.
    convert(Rho::RneFp32, s, e, F2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(fmt: Format, v: f64) -> u64 {
        fmt.from_f64(v)
    }

    fn run(in_fmt: Format, a: &[f64], b: &[f64], c: f64) -> f32 {
        let ab: Vec<u64> = a.iter().map(|&x| f(in_fmt, x)).collect();
        let bb: Vec<u64> = b.iter().map(|&x| f(in_fmt, x)).collect();
        let out = tr_fdpa(in_fmt, &ab, &bb, f(Format::Fp32, c), TrFdpaCfg::cdna3());
        f32::from_bits(out as u32)
    }

    #[test]
    fn paper_section5_cdna3_fp16() {
        // §5: fused truncated sum of products gives -2^23 - 0.5 (F=24),
        // then + 2^23 = -0.5
        let a = [-8192.0, -0.5, -0.25, -0.125, 0.0, 0.0, 0.0, 0.0];
        let b = [1024.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0];
        let d = run(Format::Fp16, &a, &b, 2f64.powi(23));
        assert_eq!(d, -0.5, "CDNA3 TF32/BF16/FP16 produce -0.5");
    }

    #[test]
    fn c_not_in_fused_sum() {
        // products alone: 1.0; c = 2^30 swamps in the two-term RD sum
        // T = 1.0 (e_max = 0), E = 30, RD_F2: 1.0 at quantum 2^(30-31)=2^-1
        // survives exactly (2 quanta); c exact. Sum = 2^30 + 1 -> RNE-FP32
        // rounds to 2^30 (tie-to-even at 2^30 quantum 2^7... inexact, rounds down)
        let d = run(Format::Fp16, &[1.0], &[1.0], 2f64.powi(30));
        assert_eq!(d, 2f32.powi(30));
    }

    #[test]
    fn round_down_bias_on_negative_tail() {
        // T = -0.625 with e_max = -1; c = 2^23 (E = 23, quantum F=24 -> 0.5,
        // F2=31 -> 2^-8). T' = RD(-0.625 at 2^-8 quanta) exact = -160 quanta.
        // Wait: F2 = 31 => quantum 2^(23-31) = 2^-8; -0.625 = -160 quanta exact.
        // Sum = 2^23 - 0.625 -> RNE-FP32 = 2^23 - 0.625 ? fp32 quantum at
        // 2^23 is 1.0: 8388607.375 -> RNE -> 8388607.5? not representable;
        // quantum in [2^22,2^23) is 0.5 -> 8388607.375 rounds to .5
        let a = [-0.5, -0.125];
        let b = [1.0, 1.0];
        let d = run(Format::Fp16, &a, &b, 2f64.powi(23));
        assert_eq!(d, 8388607.5);
    }

    #[test]
    fn asymmetry_of_rd() {
        // Φ(-A, B, -C) != -Φ(A, B, C) (paper §6.2.4).
        // T = 2^-24 + 2^-34; E = 0 (c = ±1); RD at F2 = 31:
        //   positive: T' = 2^-24 (tail dropped), S = 1 + 2^-24, RNE tie -> 1.0
        //   negative: T' = -(2^-24 + 2^-31), S past the tie -> -(1 + 2^-23)
        let a = [2f64.powi(-12), 2f64.powi(-17)];
        let b = [2f64.powi(-12), 2f64.powi(-17)];
        let pos = run(Format::Fp16, &a, &b, 1.0);
        let neg_a: Vec<f64> = a.iter().map(|x| -x).collect();
        let neg = run(Format::Fp16, &neg_a, &b, -1.0);
        assert_eq!(pos, 1.0);
        assert_eq!(neg, -(1.0 + 2f32.powi(-23)));
        assert_ne!(pos, -neg, "RD makes TR-FDPA asymmetric");
    }

    #[test]
    fn product_overflow_to_inf() {
        // BF16 supports huge values: 2^120 * 2^120 = 2^240 >= 2^128 -> +inf
        let d = run(Format::Bf16, &[2f64.powi(120)], &[2f64.powi(120)], 0.0);
        assert!(d.is_infinite() && d > 0.0);
        let d = run(Format::Bf16, &[-(2f64.powi(120))], &[2f64.powi(120)], 0.0);
        assert!(d.is_infinite() && d < 0.0);
        // opposing overflows -> NaN
        let d = run(
            Format::Bf16,
            &[2f64.powi(120), -(2f64.powi(120))],
            &[2f64.powi(120), 2f64.powi(120)],
            0.0,
        );
        assert!(d.is_nan());
    }

    #[test]
    fn no_overflow_below_2_128() {
        // 2^126 < 2^128: stays finite internally and is FP32-representable.
        let d = run(Format::Bf16, &[2f64.powi(63)], &[2f64.powi(63)], 0.0);
        assert_eq!(d, 2f32.powi(126));
    }

    #[test]
    fn exact_zero_is_positive() {
        let d = run(Format::Fp16, &[2.0, -2.0], &[1.0, 1.0], 0.0);
        assert_eq!(d.to_bits(), 0);
    }

    #[test]
    fn rne_output() {
        // T exact 1 + 2^-24, single product path: output RNE ties-to-even -> 1.0
        let d = run(Format::Fp16, &[1.0, 2f64.powi(-12)], &[1.0, 2f64.powi(-12)], 0.0);
        assert_eq!(d, 1.0);
    }
}
