//! ST-FDPA: scaled truncated fused dot-product-add (paper Algorithm 8).
//!
//! Models general MXFP8/MXFP6/MXFP4 MMA instructions: T-FDPA with two
//! per-block E8M0 scale factors whose exponents are added into every
//! product's nominal exponent before the fused summation.

use super::t_fdpa::{t_fdpa_lanes, t_fdpa_scaled, TFdpaCfg};
use crate::formats::{Format, Rho};

/// ST-FDPA over bit patterns. `alpha`/`beta` are E8M0 scale patterns.
pub fn st_fdpa(
    in_fmt: Format,
    a: &[u64],
    b: &[u64],
    c_bits: u64,
    alpha: u64,
    beta: u64,
    cfg: TFdpaCfg,
) -> u64 {
    let da = Format::E8M0.decode(alpha);
    let db = Format::E8M0.decode(beta);
    let scale_nan = da.is_nan() || db.is_nan();
    let scale_exp = if scale_nan { 0 } else { da.exp + db.exp };
    t_fdpa_scaled(in_fmt, a, b, c_bits, cfg, scale_exp, scale_nan)
}

/// Monomorphized ST-FDPA core: the E8M0 scale decode folded onto the
/// [`t_fdpa_lanes`] lane kernel. Bit-identical to [`st_fdpa`].
#[inline(always)]
pub(crate) fn st_fdpa_lanes<const L: usize, const F: i32>(
    in_fmt: Format,
    rho: Rho,
    a: &[u64],
    b: &[u64],
    c_bits: u64,
    alpha: u64,
    beta: u64,
) -> u64 {
    let da = Format::E8M0.decode(alpha);
    let db = Format::E8M0.decode(beta);
    let scale_nan = da.is_nan() || db.is_nan();
    let scale_exp = if scale_nan { 0 } else { da.exp + db.exp };
    t_fdpa_lanes::<L, F>(in_fmt, rho, a, b, c_bits, scale_exp, scale_nan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Rho;

    fn f(fmt: Format, v: f64) -> u64 {
        fmt.from_f64(v)
    }

    const CFG: TFdpaCfg = TFdpaCfg { f: 25, rho: Rho::RzFp32 };

    #[test]
    fn unit_scales_match_t_fdpa() {
        let a: Vec<u64> = [1.5, -2.0].iter().map(|&x| f(Format::Fp8E4M3, x)).collect();
        let b: Vec<u64> = [2.0, 0.5].iter().map(|&x| f(Format::Fp8E4M3, x)).collect();
        let c = f(Format::Fp32, 0.25);
        let scaled = st_fdpa(Format::Fp8E4M3, &a, &b, c, 127, 127, CFG);
        let unscaled = super::super::t_fdpa(Format::Fp8E4M3, &a, &b, c, CFG);
        assert_eq!(scaled, unscaled);
    }

    #[test]
    fn scales_shift_products_not_accumulator() {
        // alpha = 2^3, beta = 2^1: products scaled by 16, c unscaled
        let a = [f(Format::Fp8E4M3, 1.0)];
        let b = [f(Format::Fp8E4M3, 1.0)];
        let c = f(Format::Fp32, 1.0);
        let out = st_fdpa(Format::Fp8E4M3, &a, &b, c, 130, 128, CFG);
        assert_eq!(f32::from_bits(out as u32), 16.0 + 1.0);
    }

    #[test]
    fn tiny_scales_downshift() {
        let a = [f(Format::Fp8E4M3, 2.0)];
        let b = [f(Format::Fp8E4M3, 3.0)];
        let c = f(Format::Fp32, 0.0);
        // alpha = 2^-4, beta = 2^-2
        let out = st_fdpa(Format::Fp8E4M3, &a, &b, c, 123, 125, CFG);
        assert_eq!(f32::from_bits(out as u32), 6.0 / 64.0);
    }

    #[test]
    fn nan_scale_poisons() {
        let a = [f(Format::Fp8E4M3, 1.0)];
        let b = [f(Format::Fp8E4M3, 1.0)];
        let out = st_fdpa(Format::Fp8E4M3, &a, &b, 0, 0xFF, 127, CFG);
        assert_eq!(out, 0x7FFF_FFFF, "NaN scale -> NVIDIA canonical NaN");
    }

    #[test]
    fn scale_changes_truncation_outcome() {
        // Without scales: 2^20 + 2^-6 with F=25 keeps the tail; with the
        // big term scaled up by 2^6 the tail falls below the quantum.
        let a: Vec<u64> = [2f64.powi(4), 2f64.powi(-3)]
            .iter()
            .map(|&x| f(Format::Fp8E4M3, x))
            .collect();
        let b: Vec<u64> = [2f64.powi(4), 2f64.powi(-3)]
            .iter()
            .map(|&x| f(Format::Fp8E4M3, x))
            .collect();
        let base = st_fdpa(Format::Fp8E4M3, &a, &b, 0, 127, 127, CFG);
        assert_eq!(f32::from_bits(base as u32), 2f32.powi(8) + 2f32.powi(-6));
        let scaled = st_fdpa(Format::Fp8E4M3, &a, &b, 0, 127 + 12, 127 + 12, CFG);
        // products now 2^32 and 2^18: both survive F=25 relative to 2^32
        assert_eq!(f32::from_bits(scaled as u32), 2f32.powi(32) + 2f32.powi(18));
    }
}
