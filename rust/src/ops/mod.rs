//! The elementary floating-point operations that GPU MMA units are
//! composed of (paper §4.1, Algorithms 1, 3, 6–11).
//!
//! Each operation deterministically maps floating-point *bit patterns* to
//! a floating-point bit pattern. Inside the operations, intermediates are
//! fixed-point — exactly as the paper defines an elementary operation.

pub mod e_fdpa;
pub mod fma;
pub mod ftz;
pub mod gst_fdpa;
pub mod gtr_fdpa;
pub mod special;
pub mod st_fdpa;
pub mod t_fdpa;
pub mod tr_fdpa;

pub use e_fdpa::e_fdpa;
pub use fma::fma;
pub use ftz::{ftz_add, ftz_mul, flush_subnormal_input};
pub use gst_fdpa::{gst_fdpa, GstFdpaCfg};
pub use gtr_fdpa::{gtr_fdpa, GtrFdpaCfg};
pub use special::{canonical_nan, scan_specials, NanStyle, SpecialAcc, SpecialOut};
pub use st_fdpa::st_fdpa;
pub use t_fdpa::{t_fdpa, TFdpaCfg};
pub use tr_fdpa::{tr_fdpa, TrFdpaCfg};

use crate::fixedpoint::FxTerm;
use crate::formats::{Decoded, Format};

/// Maximum FDPA vector length across all modeled instructions (GST-FDPA
/// on Blackwell uses L = 64); fixed-size scratch arrays are sized by this.
pub const MAX_L: usize = 64;

/// Build the exact product term of two decoded finite values
/// (`SignedSig(a)·SignedSig(b)` with nominal exponent `Exp(a)+Exp(b)`).
#[inline]
pub(crate) fn product_term(fmt_a: Format, a: Decoded, fmt_b: Format, b: Decoded) -> FxTerm {
    FxTerm::product(
        a.sig,
        a.exp,
        fmt_a.mant_bits(),
        a.sign,
        b.sig,
        b.exp,
        fmt_b.mant_bits(),
        b.sign,
    )
}

/// Product term of two raw operand patterns: one pair-product table load
/// for the ≤ 8-bit formats, two split sub-table loads plus a narrow
/// multiply for the 16-bit formats ([`crate::formats::tables`]), falling
/// back to the decode-based construction only for the wide formats
/// (TF32/FP32/FP64). `a`/`b` are the already-decoded operands — the
/// kernels hold them for the special-value scan regardless, so the
/// fallback costs nothing extra.
#[inline]
pub(crate) fn product_term_bits(
    fmt: Format,
    a_bits: u64,
    b_bits: u64,
    a: Decoded,
    b: Decoded,
) -> FxTerm {
    if let Some(t) = crate::formats::tables::product(fmt, a_bits, fmt, b_bits) {
        return t;
    }
    if let Some(t) = crate::formats::tables::product_split(fmt, a_bits, b_bits) {
        return t;
    }
    product_term(fmt, a, fmt, b)
}

/// The accumulator as an alignment term (`SignedSig(c)`, `Exp(c)`).
#[inline]
pub(crate) fn acc_term(fmt_c: Format, c: Decoded) -> FxTerm {
    if c.is_zero() || c.sig == 0 {
        FxTerm::ZERO
    } else {
        FxTerm { neg: c.sign, mag: c.sig as u128, exp: c.exp, frac: fmt_c.mant_bits() as i32 }
    }
}

/// Sign convention for exactly-zero fused results: `+0`, unless every
/// contributing input (all products as signed zeros, and the accumulator)
/// is a negative zero. Shared by every fused operation so the Rust model
/// and the Python oracle agree bit-for-bit.
#[inline]
pub(crate) fn zero_result_negative(prod_signs: impl Iterator<Item = bool>, c_neg: bool) -> bool {
    let mut all_neg = c_neg;
    let mut any = false;
    for s in prod_signs {
        any = true;
        all_neg &= s;
    }
    let _ = any;
    all_neg
}
