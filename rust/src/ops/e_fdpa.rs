//! E-FDPA: exact fused dot-product-add (paper Algorithm 6).
//!
//! Used by BF16/FP16 MFMA instructions on AMD CDNA1. Computes
//! `c + Σ a_k·b_k` as if with infinite precision (realized by a Kulisch
//! accumulator) and rounds once to FP32 with RNE.

use super::special::{special_pattern, NanStyle, SpecialOut};
use super::{product_term_bits, scan_specials, zero_result_negative, MAX_L};
use crate::fixedpoint::Kulisch;
use crate::formats::{Decoded, Format, RoundingMode};

/// Accumulator window: BF16 products span LSBs from `2^(−133−133−14)`
/// up to `2^(127+127−14) = 2^240` (two maximum-exponent normals), with
/// magnitudes reaching `2^257`; FP32 `c` reaches down to `2^-149`.
/// LSB at −320 with 12 words (768 bits) covers bit positions −320…447
/// plus carry/sign headroom.
const LSB: i32 = -320;
const WORDS: usize = 12;

/// Exact FDPA: `RNE-FP32(c + Σ a_k b_k)` over bit patterns.
///
/// `in_fmt ∈ {BF16, FP16}`; `a`, `b` are the length-`L` vectors; `c` is an
/// FP32 pattern.
pub fn e_fdpa(in_fmt: Format, a: &[u64], b: &[u64], c_bits: u64) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let l = a.len();
    // hard assert: the stack staging below would index out of bounds, and
    // a release build must fail with the real reason, not a slice panic
    assert!(l <= MAX_L, "FDPA vector length {l} exceeds {MAX_L}");
    let c = Format::Fp32.decode(c_bits);
    // fixed-size decode staging: no heap allocation on the hot path
    let mut da = [Decoded::ZERO; MAX_L];
    let mut db = [Decoded::ZERO; MAX_L];
    for i in 0..l {
        da[i] = in_fmt.decode(a[i]);
        db[i] = in_fmt.decode(b[i]);
    }
    let (da, db) = (&da[..l], &db[..l]);

    match scan_specials(da.iter().copied().zip(db.iter().copied()), c) {
        SpecialOut::None => {}
        s => return special_pattern(s, Format::Fp32, NanStyle::Quiet),
    }

    let mut acc = Kulisch::<WORDS>::new(LSB);
    for i in 0..l {
        // product value = mag * 2^(exp - frac), via the shared product-term
        // path (split sub-table loads for the BF16/FP16 inputs here)
        let t = product_term_bits(in_fmt, a[i], b[i], da[i], db[i]);
        acc.add(t.neg, t.mag, t.exp - t.frac);
    }
    acc.add(c.sign, c.sig as u128, c.exp - 23);

    if acc.is_zero() {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 0x8000_0000 } else { 0 };
    }
    let (neg, mag, lsb) = acc.to_sign_mag();
    Format::Fp32.encode(neg, mag, lsb, RoundingMode::NearestEven)
}

/// Monomorphized E-FDPA core: chunk length `L` folded as a constant, so
/// the decode gathers and product staging are fixed-width lane loops.
/// Bit-identical to [`e_fdpa`] (the Kulisch accumulation is exact, hence
/// order-insensitive).
#[inline(always)]
pub(crate) fn e_fdpa_lanes<const L: usize>(
    in_fmt: Format,
    a: &[u64],
    b: &[u64],
    c_bits: u64,
) -> u64 {
    let a: &[u64; L] = a.try_into().expect("chunk length == L");
    let b: &[u64; L] = b.try_into().expect("chunk length == L");
    let c = Format::Fp32.decode(c_bits);
    let mut da = [Decoded::ZERO; L];
    let mut db = [Decoded::ZERO; L];
    for i in 0..L {
        da[i] = in_fmt.decode(a[i]);
    }
    for i in 0..L {
        db[i] = in_fmt.decode(b[i]);
    }

    match scan_specials(da.iter().copied().zip(db.iter().copied()), c) {
        SpecialOut::None => {}
        s => return special_pattern(s, Format::Fp32, NanStyle::Quiet),
    }

    let mut acc = Kulisch::<WORDS>::new(LSB);
    for i in 0..L {
        let t = product_term_bits(in_fmt, a[i], b[i], da[i], db[i]);
        acc.add(t.neg, t.mag, t.exp - t.frac);
    }
    acc.add(c.sign, c.sig as u128, c.exp - 23);

    if acc.is_zero() {
        let neg = zero_result_negative(
            da.iter().zip(db.iter()).map(|(x, y)| x.sign != y.sign),
            c.sign,
        );
        return if neg { 0x8000_0000 } else { 0 };
    }
    let (neg, mag, lsb) = acc.to_sign_mag();
    Format::Fp32.encode(neg, mag, lsb, RoundingMode::NearestEven)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(fmt: Format, v: f64) -> u64 {
        fmt.from_f64(v)
    }

    fn run_f32(in_fmt: Format, a: &[f64], b: &[f64], c: f64) -> f32 {
        let ab: Vec<u64> = a.iter().map(|&x| f(in_fmt, x)).collect();
        let bb: Vec<u64> = b.iter().map(|&x| f(in_fmt, x)).collect();
        let out = e_fdpa(in_fmt, &ab, &bb, f(Format::Fp32, c));
        f32::from_bits(out as u32)
    }

    #[test]
    fn exact_small_dot() {
        let d = run_f32(Format::Fp16, &[1.5, -2.0], &[2.0, 0.5], 0.25);
        assert_eq!(d, 1.5 * 2.0 - 2.0 * 0.5 + 0.25);
    }

    #[test]
    fn paper_section5_cdna1_fp16() {
        // §5: FP16 E-FDPA (L=4) yields the exact result -0.875
        let a = [-8192.0, -0.5, -0.25, -0.125];
        let b = [1024.0, 1.0, 1.0, 1.0];
        let d = run_f32(Format::Fp16, &a, &b, 2f64.powi(23));
        assert_eq!(d, -0.875);
    }

    #[test]
    fn infinite_precision_inside() {
        // 2^30 + 2^-30 - 2^30 survives exactly (would vanish in f32 adds)
        let d = run_f32(
            Format::Bf16,
            &[2f64.powi(15), 2f64.powi(-15), -(2f64.powi(15))],
            &[2f64.powi(15), 2f64.powi(-15), 2f64.powi(15)],
            0.0,
        );
        assert_eq!(d, 2f32.powi(-30));
    }

    #[test]
    fn single_rounding_at_output() {
        // exact sum 1 + 2^-24 rounds once: tie-to-even -> 1.0
        let d = run_f32(Format::Fp16, &[1.0, 2f64.powi(-12)], &[1.0, 2f64.powi(-12)], 0.0);
        assert_eq!(d, 1.0);
        // 1 + 3*2^-25: not a tie at fp32; exact sum rounds to 1 + 2^-23
        let d = run_f32(
            Format::Fp16,
            &[1.0, 2f64.powi(-12), 2f64.powi(-13)],
            &[1.0, 2f64.powi(-12), 2f64.powi(-12)],
            0.0,
        );
        assert_eq!(d, 1.0 + 2f32.powi(-23));
    }

    #[test]
    fn subnormal_inputs_exact() {
        // CDNA1 E-FDPA does NOT flush: min fp16 subnormal 2^-24 squared = 2^-48
        let d = run_f32(Format::Fp16, &[2f64.powi(-24)], &[2f64.powi(-24)], 0.0);
        assert_eq!(d, 2f32.powi(-48));
    }

    #[test]
    fn cancellation_to_zero_is_positive() {
        let d = run_f32(Format::Fp16, &[2.0, -2.0], &[3.0, 3.0], 0.0);
        assert_eq!(d.to_bits(), 0);
    }

    #[test]
    fn all_negative_zero_inputs_give_negative_zero() {
        let a = [f(Format::Fp16, -0.0)];
        let b = [f(Format::Fp16, 0.0)];
        let out = e_fdpa(Format::Fp16, &a, &b, f(Format::Fp32, -0.0));
        assert_eq!(out, 0x8000_0000);
    }

    #[test]
    fn specials() {
        let inf = f(Format::Fp16, f64::INFINITY);
        let one = f(Format::Fp16, 1.0);
        let out = e_fdpa(Format::Fp16, &[inf], &[one], 0);
        assert_eq!(out, 0x7F80_0000);
        let out = e_fdpa(Format::Fp16, &[inf, inf], &[one, f(Format::Fp16, -1.0)], 0);
        assert_eq!(out, 0x7FC0_0000);
    }
}
