//! FTZ-Add and FTZ-Mul (paper Algorithm 1): the non-standard binary
//! operations behind AMD CDNA2's FP16/BF16 MFMA instructions.
//!
//! `z = RNE-FP32(x ∘ y)`, with subnormal FP32 outputs flushed to a
//! sign-preserved zero. Inputs are BF16/FP16/FP32; the host `f32`/`f64`
//! arithmetic below realizes RNE exactly (products of ≤11-bit significands
//! are exact in `f64`, and the final `f64 → f32` narrowing is a single
//! correctly-rounded step because the `f64` intermediate is exact).

use super::special::{canonical_nan, NanStyle};
use crate::formats::Format;

/// Flush a subnormal *input* to positive zero (paper Algorithm 2 line 1-3:
/// CDNA2 flushes input subnormals to `+0.0` before multiplication).
#[inline]
pub fn flush_subnormal_input(fmt: Format, bits: u64) -> u64 {
    let d = fmt.decode(bits);
    if d.is_subnormal(fmt) && !d.is_zero() {
        0 // +0.0
    } else {
        bits
    }
}

#[inline]
fn flush_output(z: f32) -> f32 {
    if z != 0.0 && z.abs() < f32::MIN_POSITIVE {
        // sign-preserved flush: z * 0.0
        z * 0.0
    } else {
        z
    }
}

#[inline]
fn canon(z: f32) -> u64 {
    if z.is_nan() {
        canonical_nan(Format::Fp32, NanStyle::Quiet)
    } else {
        z.to_bits() as u64
    }
}

/// FTZ-Add over FP32 bit patterns: `RNE-FP32(x + y)` then output flush.
#[inline]
pub fn ftz_add(x_bits: u64, y_bits: u64) -> u64 {
    let x = f32::from_bits(x_bits as u32);
    let y = f32::from_bits(y_bits as u32);
    canon(flush_output(x + y))
}

/// FTZ-Mul over `fmt ∈ {BF16, FP16, FP32}` inputs, FP32 output.
#[inline]
pub fn ftz_mul(fmt: Format, x_bits: u64, y_bits: u64) -> u64 {
    // Exact in f64 (≤ 24-bit significands, exponent range well inside f64),
    // then one correctly-rounded narrowing to f32. For ≤ 16-bit inputs the
    // `to_f64` calls are single loads from the formats::tables f64 LUT.
    let x = fmt.to_f64(x_bits);
    let y = fmt.to_f64(y_bits);
    canon(flush_output((x * y) as f32))
}

/// Monomorphized FTZ-AddMul dot-product-accumulate (Algorithm 2): the
/// pairing parameter `P` folded as a constant, so the product stage is a
/// fixed-width lane loop and the pairwise summation tree is selected at
/// compile time. Requires `a.len() % P == 0` (the compiled-kernel lookup
/// guarantees it); bit-identical to the interpreter's whole-chunk path.
#[inline(always)]
pub(crate) fn ftz_dpa_lanes<const P: usize>(fmt: Format, a: &[u64], b: &[u64], c: u64) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % P, 0);
    // input subnormal flushing (A, B, and C)
    let mut d = flush_subnormal_input(Format::Fp32, c);
    let mut k = 0;
    while k < a.len() {
        let mut prods = [0u64; P];
        for i in 0..P {
            prods[i] = ftz_mul(
                fmt,
                flush_subnormal_input(fmt, a[k + i]),
                flush_subnormal_input(fmt, b[k + i]),
            );
        }
        let s = match P {
            1 => prods[0],
            2 => ftz_add(prods[0], prods[1]),
            4 => {
                let s01 = ftz_add(prods[0], prods[1]);
                let s23 = ftz_add(prods[2], prods[3]);
                ftz_add(s01, s23)
            }
            _ => {
                // unmodeled P: pairwise left-to-right, as the interpreter
                let mut s = ftz_add(prods[0], prods[1]);
                for &q in &prods[2..P] {
                    s = ftz_add(s, q);
                }
                s
            }
        };
        d = ftz_add(d, s);
        k += P;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_is_rne_fp32() {
        let a = (1.0f32).to_bits() as u64;
        let b = (2f32.powi(-24)).to_bits() as u64; // tie: rounds to even (1.0)
        assert_eq!(ftz_add(a, b), (1.0f32).to_bits() as u64);
    }

    #[test]
    fn add_flushes_subnormal_result() {
        // 2^-126 - 2^-127 = 2^-127: subnormal -> flushed to +0
        let a = (2f32.powi(-126)).to_bits() as u64;
        let b = (-2f32.powi(-127)).to_bits() as u64;
        let z = ftz_add(a, b);
        assert_eq!(z, 0, "positive subnormal result flushes to +0");
        // negative: -(2^-127) stays negative zero
        let z = ftz_add(b, 0);
        assert_eq!(z, (-0.0f32).to_bits() as u64, "sign-preserved flush");
    }

    #[test]
    fn mul_fp16_inputs() {
        let f = Format::Fp16;
        let a = f.from_f64(1.5);
        let b = f.from_f64(-2.0);
        assert_eq!(ftz_mul(f, a, b), (-3.0f32).to_bits() as u64);
    }

    #[test]
    fn mul_flushes_subnormal_product() {
        let f = Format::Fp16;
        // 2^-14 * 2^-14 * ... -> need product < 2^-126: fp16 min normal 2^-14;
        // min subnormal 2^-24: 2^-24 * 2^-24 = 2^-48 (normal). FP16 products
        // cannot be FP32-subnormal, so check via BF16.
        let bf = Format::Bf16;
        let a = bf.from_f64(2f64.powi(-100));
        let b = bf.from_f64(2f64.powi(-30));
        assert_eq!(ftz_mul(bf, a, b), 0, "2^-130 flushes to +0");
        let a = bf.from_f64(-(2f64.powi(-100)));
        assert_eq!(
            ftz_mul(bf, a, b),
            (-0.0f32).to_bits() as u64,
            "sign-preserved flush"
        );
        let _ = f;
    }

    #[test]
    fn input_flush_helper() {
        let f = Format::Fp16;
        let sub = 0x0001u64; // min fp16 subnormal
        assert_eq!(flush_subnormal_input(f, sub), 0);
        let neg_sub = 0x8001u64;
        assert_eq!(flush_subnormal_input(f, neg_sub), 0, "flush to +0, not -0");
        let normal = f.from_f64(1.0);
        assert_eq!(flush_subnormal_input(f, normal), normal);
        let zero = 0x8000u64; // -0 stays -0 (not subnormal)
        assert_eq!(flush_subnormal_input(f, zero), zero);
    }

    #[test]
    fn nan_canonicalized() {
        let nan = f32::NAN.to_bits() as u64;
        assert_eq!(ftz_add(nan, 0), 0x7FC0_0000);
        assert_eq!(ftz_mul(Format::Fp32, nan, (1.0f32).to_bits() as u64), 0x7FC0_0000);
    }

    #[test]
    fn inf_arithmetic() {
        let inf = f32::INFINITY.to_bits() as u64;
        let ninf = f32::NEG_INFINITY.to_bits() as u64;
        assert_eq!(ftz_add(inf, (1.0f32).to_bits() as u64), inf);
        assert_eq!(ftz_add(inf, ninf), 0x7FC0_0000);
    }
}
