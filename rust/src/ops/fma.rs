//! Standard IEEE-754 fused multiply-add (paper Algorithm 3).
//!
//! All FP64 MMA instructions on NVIDIA GPUs and all FP64/FP32 MMA
//! instructions on AMD GPUs reduce to chains of this operation. The host
//! `mul_add` is IEEE-correct (single rounding, RNE, gradual underflow) on
//! every platform Rust targets, so it serves as the reference
//! implementation; results are NaN-canonicalized to the quiet pattern.
//! This is the one kernel the `formats::tables` fast path deliberately
//! bypasses: FP32/FP64 operands are too wide to tabulate, and the host
//! FMA never decodes them.

use super::special::{canonical_nan, NanStyle};
use crate::formats::Format;

/// Standard FMA over bit patterns of `fmt ∈ {FP32, FP64}`.
#[inline]
pub fn fma(fmt: Format, a_bits: u64, b_bits: u64, c_bits: u64) -> u64 {
    match fmt {
        Format::Fp32 => {
            let a = f32::from_bits(a_bits as u32);
            let b = f32::from_bits(b_bits as u32);
            let c = f32::from_bits(c_bits as u32);
            let d = a.mul_add(b, c);
            if d.is_nan() {
                canonical_nan(Format::Fp32, NanStyle::Quiet)
            } else {
                d.to_bits() as u64
            }
        }
        Format::Fp64 => {
            let a = f64::from_bits(a_bits);
            let b = f64::from_bits(b_bits);
            let c = f64::from_bits(c_bits);
            let d = a.mul_add(b, c);
            if d.is_nan() {
                canonical_nan(Format::Fp64, NanStyle::Quiet)
            } else {
                d.to_bits()
            }
        }
        other => panic!("FMA model only defined for FP32/FP64, got {:?}", other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rounding_fp32() {
        // a*b+c where a*b is inexact in fp32 but the fused result differs
        // from mul-then-add: classic witness.
        let a = 1.0f32 + 2f32.powi(-12);
        let b = 1.0f32 + 2f32.powi(-12);
        let c = -(1.0f32 + 2f32.powi(-11));
        let fused = fma(
            Format::Fp32,
            a.to_bits() as u64,
            b.to_bits() as u64,
            c.to_bits() as u64,
        );
        let fused = f32::from_bits(fused as u32);
        let unfused = a * b + c;
        assert_eq!(fused, 2f32.powi(-24), "exact residual via fused path");
        assert_ne!(fused, unfused);
    }

    #[test]
    fn fp64_exactness() {
        let d = fma(
            Format::Fp64,
            (2f64.powi(52) + 1.0).to_bits(),
            (2f64.powi(52) + 1.0).to_bits(),
            (-(2f64.powi(104))).to_bits(),
        );
        // (2^52+1)^2 - 2^104 = 2^53 + 1
        assert_eq!(f64::from_bits(d), 2f64.powi(53) + 1.0);
    }

    #[test]
    fn nan_canonical() {
        let nan = f64::NAN.to_bits();
        assert_eq!(fma(Format::Fp64, nan, 0, 0), 0x7FF8_0000_0000_0000);
        let nan32 = (f32::NAN.to_bits()) as u64;
        assert_eq!(fma(Format::Fp32, nan32, 0, 0), 0x7FC0_0000);
    }

    #[test]
    fn inf_times_zero() {
        let inf = (f32::INFINITY.to_bits()) as u64;
        assert_eq!(fma(Format::Fp32, inf, 0, 0), 0x7FC0_0000);
    }

    #[test]
    fn subnormal_gradual_underflow() {
        // 2^-100 * 2^-100 + 2^-149 must hit the subnormal range exactly
        let a = (2f32.powi(-100)).to_bits() as u64;
        let c = (2f32.powi(-149)).to_bits() as u64;
        let d = fma(Format::Fp32, a, a, c);
        // 2^-200 rounds away inside RNE against the 2^-149 quantum:
        // result = 2^-149 (the tiny product underflows)
        assert_eq!(f32::from_bits(d as u32), 2f32.powi(-149));
    }
}
