//! # MMA-Sim
//!
//! Bit-accurate reference models of GPU matrix multiply-accumulate units
//! (NVIDIA Tensor Cores, AMD Matrix Cores), reproducing
//! *"Bit-Accurate Modeling of GPU Matrix Multiply-Accumulate Units:
//! Demystifying Numerical Discrepancy and Accuracy"* (MMA-Sim).
//!
//! The crate is organized in layers, with [`session`] as the front door:
//!
//! - [`session`] — **the primary API**: a [`SessionBuilder`] →
//!   [`Session`] facade that resolves instructions (with ambiguity
//!   detection), validates every operand against the instruction's
//!   shape/format/scale spec ([`ApiError`] instead of panics),
//!   reuses scratch across runs, and exposes `run` / `run_batch` /
//!   `gemm` / `probe` / `infer` / `campaign` plus JSON-lines
//!   serialization ([`session::json`]), the long-running verification
//!   service ([`session::serve`]), and process-level sharding
//!   ([`session::shard`]). Sharded work is *typed*: [`session::work`]
//!   defines the one `WorkItem`/`WorkResult` model every tier moves —
//!   campaign verification jobs and GEMM row bands are two variants of
//!   the same enum, dispatched by one generic `ShardPool` engine over a
//!   `WorkerTransport`, requeued from dying children, and merged back
//!   deterministically (`Session::shard_campaign` /
//!   `Session::shard_gemm`). GEMM's shared B operand travels through a
//!   content-addressed `OperandStore` (`{"put":{"addr":…,"matrix":…}}`
//!   frames, FNV-1a64‖SipHash-2-4 addresses over the canonical operand
//!   JSON, workers answering `{"need":addr}` on a miss), so band items
//!   reference operands by hash instead of relying on
//!   connection-sticky `set_b` state. The pool is hardened for unattended
//!   fleets: per-job reply deadlines retire hung-but-alive children,
//!   respawns back off on a deterministic exponential schedule against a
//!   launch budget, a job that keeps felling workers is quarantined into
//!   an explicit partial report, and each child's last stderr lines ride
//!   along in every `ApiError::Shard`. The matching fault-injection
//!   harness lives in [`session::faults`]: seeded, reproducible
//!   crash/hang/garbage/truncate/delay schedules applied through a
//!   `ChaosTransport` decorator (in-process) or the workers' own
//!   `--chaos` flag (real processes). Above the pool sits the network
//!   service tier ([`session::net`], `mma-sim serve --tcp`): many
//!   concurrent TCP clients speak the same JSON-lines protocol
//!   per connection (framed by [`session::framing`]), multiplexed onto
//!   one shared long-lived `ShardPool` in service mode with explicit
//!   backpressure (`{"ok":false,"retry":true,...}` instead of unbounded
//!   queueing), a content-addressed result cache
//!   ([`session::net::cache`]: canonical-JSON work-item keys, vendored
//!   FNV-1a/SipHash addressing, persistent warm-restart artifacts under
//!   `--cache-dir` — band results included, so a repeated GEMM band is
//!   a cache hit with zero pool submissions), and a counters surface
//!   ([`session::net::stats`], the `{"stats":true}` request). At the
//!   top sits the multi-host fleet tier ([`session::fleet`], `mma-sim
//!   shard --hosts hosts.json`, campaign and `--gemm` alike): a
//!   `TcpTransport` that plugs remote `serve --tcp`
//!   daemons into the same hardened `ShardPool` as worker connections —
//!   per-host liveness probes, reconnect with the pool's capped
//!   exponential backoff, host-level quarantine after a failure budget
//!   ([`session::fleet::hosts`] is the `hosts.json` schema),
//!   work-stealing rebalance away from slow hosts, client-side
//!   backpressure resubmits, per-host chaos (`Disconnect` /
//!   `Partition` / `SlowHost` in [`session::faults`]), and per-host
//!   counters — with `--deterministic` fleet bytes pinned identical to
//!   the single-process run. Start here; the layers below are the
//!   machinery it drives.
//! - [`error`] — the structured [`ApiError`] every validated entry point
//!   rejects malformed input with (a leaf module, so the layers below can
//!   return it without depending on the facade above them).
//! - [`formats`] — software floating-point formats (FP64 … FP4, E8M0, UE4M3),
//!   decode/encode with every rounding mode, the paper's Table 2
//!   conversion functions, and the `formats::tables` LUT fast path
//!   (table-driven decode and exact pair products for narrow formats).
//! - [`fixedpoint`] — the wide fixed-point machinery (aligned truncation
//!   `RZ_F`/`RD_F`, exact Kulisch-style accumulation) that the fused
//!   operations are built from.
//! - [`ops`] — the nine elementary operations of the paper
//!   (Algorithms 1, 3, 6–11): FTZ-Add/Mul, FMA, E-FDPA, T-FDPA, ST-FDPA,
//!   GST-FDPA, TR-FDPA, GTR-FDPA. Each fused family carries two forms:
//!   the runtime-parameterized entry (`t_fdpa`, `gst_fdpa`, …) and a
//!   const-generic `*_lanes` core with the vector length, summation
//!   precision, and scale-block geometry folded as compile-time
//!   constants — the building blocks the compiled kernel layer
//!   monomorphizes over.
//! - [`models`] — matrix-level arithmetic-behavior models Φ
//!   (Algorithms 2, 4, 5), in two bit-identical implementations:
//!   the *interpreter* (`run_*` kernels reading chunk length, widths,
//!   and rounding mode out of the resolved spec at runtime — the
//!   explicit reference implementation) and the *compiled* layer
//!   (`models::compiled`: every registry (family × format × L)
//!   combination macro-instantiated into a straight-line kernel over
//!   the `ops` lane cores, resolved once at `MmaModel::new`).
//!   Execution runs the compiled kernel whenever the spec is in the
//!   generated set (every registry instruction) and falls back to the
//!   interpreter for ragged-K or non-registry parameterizations;
//!   `tests/compiled_kernels.rs` is the differential proof. The
//!   execution core is zero-copy and strided:
//!   `MmaModel::execute_view_into` reads operands in place through
//!   [`interface::MatRef`] views, pretransposes B once per case into a
//!   scratch [`interface::BPanel`] (contiguous columns, no per-output
//!   gathering), and resolves the kernel function once before the m×n
//!   loop.
//! - [`isa`] — the instruction registry for the ten GPU architectures
//!   (paper Tables 3–7), with fallible fragment resolution
//!   ([`isa::resolve`]).
//! - [`interface`] — the black-box `MmaInterface` abstraction that CLFP
//!   probes (a Rust model, a PJRT-loaded artifact, or a mystery model),
//!   the order-preserving parallel batch engine, and the borrowed
//!   matrix-view types ([`interface::MatRef`] / [`interface::MatMut`] /
//!   [`interface::BPanel`]) the strided execution core is built on.
//! - [`gemm`] — the tiled arbitrary-shape GEMM executor built from one
//!   instruction; tiles are strided windows into the caller's matrices
//!   (no operand staging) and the accumulator chain lives directly in the
//!   output matrix. Fallible entry: `TiledGemm::try_execute` (validated
//!   facade entry: [`session::Session::gemm`]). `gemm::band_groups` is
//!   the row-band plan shared by the in-process threaded executor and
//!   the cross-process shard runner.
//! - [`clfp`] — the closed-loop feature-probing framework (paper §3).
//! - [`analysis`] — discrepancy (Table 8), error bounds (Table 9), risky
//!   designs (Table 10), summation trees (Figure 2), rounding bias
//!   (Figure 3).
//! - [`coordinator`] — the thread-pool continuous-verification service,
//!   streaming batched jobs through the zero-allocation batch engine
//!   (served over JSON lines by [`session::serve`]).
//! - [`runtime`] — PJRT CPU client wrapper that loads AOT artifacts
//!   produced by `python/compile/aot.py` and exposes them as
//!   `MmaInterface`s.

// Clippy triage (PR 6, `-D warnings` now enforced in CI): these two lints
// conflict with the house style of the bit-exact kernels and are allowed
// crate-wide rather than sprinkled per-function.
// - `needless_range_loop`: the lane kernels index several fixed-size
//   arrays in lockstep (`da[i]`, `db[i]`, `terms[i]`); iterator zips would
//   obscure the lane structure the monomorphization exists to expose.
// - `too_many_arguments`: the `*_lanes` cores and `FxTerm::product` take
//   the full decoded operand tuple by design — bundling them into structs
//   would reintroduce the per-call packing the compiled path removes.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod analysis;
pub mod clfp;
pub mod coordinator;
pub mod error;
pub mod fixedpoint;
pub mod gemm;
pub mod formats;
pub mod interface;
pub mod isa;
pub mod mitigations;
pub mod models;
pub mod ops;
pub mod runtime;
pub mod session;
pub mod util;

pub use formats::{Format, RoundingMode};
pub use interface::{BitMatrix, MmaInterface};
pub use isa::{Arch, Instruction};
pub use session::{ApiError, RunOutput, Session, SessionBuilder};
