//! The batch execution engine end-to-end: batched results must be
//! bit-identical to per-case scalar execution through every entry point
//! (trait-object dispatch, the parallel driver, the coordinator pool), for
//! every model family in the registry.

use std::sync::Arc;

use mma_sim::clfp::random_case_batch;
use mma_sim::coordinator::{Coordinator, VerifyPair};
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::{
    parallel_execute_batch, parallel_execute_batch_with, MmaFormats, MmaInterface,
};
use mma_sim::isa;
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::util::Rng;

#[test]
fn batch_equals_scalar_for_every_registry_instruction() {
    let mut rng = Rng::new(0xE0E0);
    for instr in isa::registry() {
        if instr.m * instr.n > 1024 {
            continue; // keep the sweep snappy; shapes repeat across sizes
        }
        let model = instr.model();
        let iface: &dyn MmaInterface = &model;
        let cases = random_case_batch(&mut rng, iface, 5, 0);
        let batched = iface.execute_batch(&cases);
        assert_eq!(batched.len(), cases.len(), "{}", instr.name);
        for (cs, got) in cases.iter().zip(batched.iter()) {
            let want = iface.execute(&cs.a, &cs.b, &cs.c, None);
            assert_eq!(want.data, got.data, "{}", instr.name);
        }
    }
}

#[test]
fn parallel_driver_is_bit_identical_for_any_thread_count() {
    let model = MmaModel::new(
        "par",
        (16, 16, 32),
        MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 },
        ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 },
    );
    let mut rng = Rng::new(0xF00D);
    let cases = random_case_batch(&mut rng, &model, 37, 0);
    let serial = model.execute_batch(&cases);
    for threads in [2, 3, 5, 16, 64] {
        let par = parallel_execute_batch_with(&model, &cases, threads);
        assert_eq!(par.len(), serial.len());
        for (i, (s, p)) in serial.iter().zip(par.iter()).enumerate() {
            assert_eq!(s.data, p.data, "case {i} threads {threads}");
        }
    }
    let auto = parallel_execute_batch(&model, &cases);
    for (s, p) in serial.iter().zip(auto.iter()) {
        assert_eq!(s.data, p.data);
    }
}

#[test]
fn coordinator_batch_path_still_catches_divergence() {
    // The worker now routes through execute_batch; a one-parameter DUT
    // perturbation must still be detected, and a matching pair must not
    // regress to false positives.
    let fmts = MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 };
    let mk = |f: i32| {
        MmaModel::new(
            format!("f{f}"),
            (8, 8, 16),
            fmts,
            ModelSpec::TFdpa { l_max: 16, f, rho: Rho::RzFp32 },
        )
    };
    let pairs = vec![
        VerifyPair { name: "same".into(), dut: Arc::new(mk(25)), golden: Arc::new(mk(25)) },
        VerifyPair { name: "diff".into(), dut: Arc::new(mk(24)), golden: Arc::new(mk(25)) },
    ];
    let coord = Coordinator::new(pairs, 4, 8);
    let report = coord.run_campaign(4, 100, 99).unwrap();
    assert_eq!(report.pairs["same"].mismatches, 0);
    assert!(report.pairs["diff"].mismatches > 0, "F=24 vs F=25 must diverge");
    let fm = report.pairs["diff"].first_mismatch.as_ref().expect("mismatch recorded");
    assert!(!fm.a.is_empty(), "reproduction inputs captured from the batch");
    coord.shutdown();
}

#[test]
fn scaled_interfaces_batch_with_scale_operands() {
    // MX-scaled instruction through the batch API with explicit scales.
    let instr = isa::registry()
        .into_iter()
        .find(|i| matches!(i.class, isa::InputClass::Mxfp8))
        .expect("an MXFP8 instruction in the registry");
    let model = instr.model();
    let spec = model.scale_spec().expect("scaled");
    let (m, n, k) = model.shape();
    let nblk = k / spec.kblock;
    let mut rng = Rng::new(0x5CA1E);
    let mut cases = random_case_batch(&mut rng, &model, 4, 0);
    for cs in cases.iter_mut() {
        let mut sa =
            mma_sim::interface::BitMatrix::zeros(m, nblk, spec.fmt);
        let mut sb =
            mma_sim::interface::BitMatrix::zeros(nblk, n, spec.fmt);
        for v in sa.data.iter_mut() {
            *v = 124 + rng.below(8); // E8M0 exponents around 2^0
        }
        for v in sb.data.iter_mut() {
            *v = 124 + rng.below(8);
        }
        cs.scales = Some((sa, sb));
    }
    let batched = model.execute_batch(&cases);
    for (cs, got) in cases.iter().zip(batched.iter()) {
        let want = model.execute(&cs.a, &cs.b, &cs.c, cs.scales());
        assert_eq!(want.data, got.data, "{}", instr.name);
    }
}
