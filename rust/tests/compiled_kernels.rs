//! Differential proof that the monomorphized (spec-compiled) kernels are
//! bit-identical to the interpreter they were compiled from.
//!
//! `MmaModel` resolves every registry instruction to a compiled kernel at
//! construction; the interpreter stays behind as the reference
//! implementation, reachable via `dpa_reference`/`execute_reference_into`.
//! These tests drive both paths over the full registry (every family, both
//! vendors), three input classes per instruction, randomized scale bit
//! patterns (including NaN/extreme scales), and the edge shapes from the
//! view-engine suite (multiblock ST, ragged-K GST/TR) — asserting exact
//! bit equality of the output matrices, plus that the compiled/fallback
//! routing itself is what the lookup gates promise.

use mma_sim::clfp::random_inputs;
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::{BitMatrix, MmaCase, MmaFormats, MmaInterface};
use mma_sim::isa;
use mma_sim::models::{DpaScratch, MmaModel, ModelSpec};
use mma_sim::util::Rng;

/// Random scale operands matching the model's block-scale spec (arbitrary
/// bit patterns: both paths must agree even on NaN/extreme scales).
fn random_scales(rng: &mut Rng, model: &MmaModel) -> Option<(BitMatrix, BitMatrix)> {
    let spec = model.scale_spec()?;
    let (m, n, _) = model.shape();
    let nblk = model.scale_blocks();
    let mut sa = BitMatrix::zeros(m, nblk, spec.fmt);
    let mut sb = BitMatrix::zeros(nblk, n, spec.fmt);
    for v in sa.data.iter_mut() {
        *v = rng.bits(spec.fmt.width());
    }
    for v in sb.data.iter_mut() {
        *v = rng.bits(spec.fmt.width());
    }
    Some((sa, sb))
}

/// Run the hot path (compiled where available) and the forced-interpreter
/// path through the identical view engine; return both output matrices.
fn both_paths(
    model: &MmaModel,
    case: &MmaCase,
    scratch: &mut DpaScratch,
) -> (BitMatrix, BitMatrix) {
    let (m, n, _) = model.shape();
    let mut hot = BitMatrix::zeros(m, n, model.formats.d);
    let mut reference = BitMatrix::zeros(m, n, model.formats.d);
    model.execute_into(&case.a, &case.b, &case.c, case.scales(), &mut hot, scratch);
    model.execute_reference_into(
        &case.a,
        &case.b,
        &case.c,
        case.scales(),
        &mut reference,
        scratch,
    );
    (hot, reference)
}

#[test]
fn registry_compiled_kernels_match_interpreter_bitwise() {
    // Every instruction must (a) actually route through a compiled kernel
    // and (b) produce bit-identical output to the interpreter across all
    // three input classes and random scale patterns.
    let mut rng = Rng::new(0xC0DE);
    let mut scratch = DpaScratch::default();
    for instr in isa::registry() {
        let model = instr.model();
        assert!(
            model.is_compiled(),
            "{} {} did not resolve to a compiled kernel",
            instr.arch.target(),
            instr.name
        );
        for t in 0..3 {
            let (a, b, c) = random_inputs(&mut rng, &model, t);
            let mut case = MmaCase::new(a, b, c);
            case.scales = random_scales(&mut rng, &model);
            let (hot, reference) = both_paths(&model, &case, &mut scratch);
            assert_eq!(
                hot.data, reference.data,
                "{} {} (class {t})",
                instr.arch.target(),
                instr.name
            );
        }
    }
}

#[test]
fn registry_dpa_matches_dpa_reference() {
    // The one-shot entry points agree too: a single dot product through
    // `dpa` (compiled) and `dpa_reference` (interpreter) bit-for-bit.
    let mut rng = Rng::new(0xD07);
    for instr in isa::registry() {
        let model = instr.model();
        let (a, b, c) = random_inputs(&mut rng, &model, 2);
        let nblk = model.scale_blocks();
        let scales = random_scales(&mut rng, &model);
        let (sa, sb): (Vec<u64>, Vec<u64>) = match &scales {
            Some((sa, sb)) => (
                (0..nblk).map(|blk| sa.get(0, blk)).collect(),
                (0..nblk).map(|r| sb.get(r, 0)).collect(),
            ),
            None => (Vec::new(), Vec::new()),
        };
        let arow: Vec<u64> = (0..model.k).map(|kk| a.get(0, kk)).collect();
        let bcol: Vec<u64> = (0..model.k).map(|kk| b.get(kk, 0)).collect();
        let c00 = c.get(0, 0);
        assert_eq!(
            model.dpa(&arow, &bcol, c00, &sa, &sb),
            model.dpa_reference(&arow, &bcol, c00, &sa, &sb),
            "{} {}",
            instr.arch.target(),
            instr.name
        );
    }
}

#[test]
fn edge_shapes_route_and_match() {
    // Multiblock ST (K = 3 × kblock): whole chunks, so it *must* compile;
    // the per-chunk scale-block indexing is the hazard being pinned.
    let st = MmaModel::new(
        "st-multiblock",
        (4, 4, 96),
        MmaFormats {
            a: Format::Fp8E4M3,
            b: Format::Fp8E4M3,
            c: Format::Fp32,
            d: Format::Fp32,
        },
        ModelSpec::StFdpa { l_max: 32, f: 25, rho: Rho::RzFp32, kblock: 32 },
    );
    assert!(st.is_compiled(), "whole-chunk multiblock ST must compile");

    // Ragged-K GST (the view-engine edge shape): the final chunk spans a
    // partial scale block, so the lookup must refuse and fall back.
    let gst = MmaModel::new(
        "gst-ragged",
        (4, 4, 40),
        MmaFormats {
            a: Format::Fp4E2M1,
            b: Format::Fp4E2M1,
            c: Format::Fp32,
            d: Format::Fp32,
        },
        ModelSpec::GstFdpa {
            l: 32,
            g: 16,
            f: 35,
            rho: Rho::RzFp32,
            kblock: 16,
            scale_fmt: Format::E8M0,
        },
    );
    assert!(!gst.is_compiled(), "ragged-K GST must stay on the interpreter");

    // Ragged-K TR: 21 % 8 != 0 — interpreter fallback.
    let tr = MmaModel::new(
        "tr-ragged",
        (4, 4, 21),
        MmaFormats {
            a: Format::Fp16,
            b: Format::Fp16,
            c: Format::Fp32,
            d: Format::Fp32,
        },
        ModelSpec::TrFdpa { l_max: 8, f: 24, f2: 31 },
    );
    assert!(!tr.is_compiled(), "ragged-K TR must stay on the interpreter");

    // Whatever the routing, both entry points agree bit-for-bit.
    let mut rng = Rng::new(0xED6E);
    let mut scratch = DpaScratch::default();
    for model in [&st, &gst, &tr] {
        for t in 0..6 {
            let (a, b, c) = random_inputs(&mut rng, model, t);
            let mut case = MmaCase::new(a, b, c);
            case.scales = random_scales(&mut rng, model);
            let (hot, reference) = both_paths(model, &case, &mut scratch);
            assert_eq!(hot.data, reference.data, "{} (class {})", model.name, t % 3);
        }
    }
}
