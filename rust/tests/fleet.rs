//! Multi-host fleet integration: real `serve --tcp` worker daemons on
//! loopback, driven through `session::fleet::TcpTransport` by the same
//! hardened `ShardPool` that drives local child processes.
//!
//! The contract under test is the ISSUE-9 acceptance bar: under any
//! chaos schedule in which every job still completes — dead daemons,
//! dropped connections, partitions, persistently slow hosts — the
//! `--deterministic` fleet output is byte-identical to the
//! single-process run; and a host that exhausts its failure budget
//! yields an explicit quarantined partial report that round-trips
//! through the `CampaignReport` JSON codec, never a hang, never
//! silently wrong bytes.
//!
//! PR 10 extends the same contract to GEMM: band work items and
//! content-addressed operand `put` frames ride the same daemon
//! connections, and the gathered output must be bit-identical to the
//! in-process `TiledGemm` — including under a mid-run daemon kill.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::Ordering;
use std::time::Duration;

use mma_sim::coordinator::{CampaignReport, Job};
use mma_sim::gemm::TiledGemm;
use mma_sim::interface::{BitMatrix, MmaFormats};
use mma_sim::isa::Arch;
use mma_sim::session::json::{self, JsonValue};
use mma_sim::session::shard::{shard_campaign, ProcessTransport, ShardConfig};
use mma_sim::session::{ChaosPlan, FleetTopology, Session, SessionBuilder, TcpTransport};
use mma_sim::util::Rng;

const PAIR: &str = "sm70 HMMA.884.F32.F16";

fn binary() -> &'static str {
    env!("CARGO_BIN_EXE_mma-sim")
}

/// A real worker daemon on an ephemeral loopback port, killed on drop.
struct Daemon {
    child: Child,
    addr: String,
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_daemon() -> Daemon {
    let mut child = Command::new(binary())
        .args([
            "serve",
            "--tcp",
            "127.0.0.1:0",
            "--workers",
            "1",
            "--child-workers",
            "1",
            "--deterministic",
        ])
        .env("MMA_SIM_THREADS", "1")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn serve --tcp daemon");
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().expect("piped stdout"))
        .read_line(&mut line)
        .expect("daemon announces its port");
    let addr = JsonValue::parse(line.trim())
        .expect("listening frame parses")
        .get("listening")
        .and_then(|a| a.as_str())
        .expect("listening frame carries the address")
        .to_string();
    Daemon { child, addr }
}

fn jobs(n: u64, batch: usize) -> Vec<Job> {
    (0..n).map(|i| Job { id: i, pair: PAIR.into(), batch, seed: 0x9000 + i }).collect()
}

/// The byte-identity baseline: the same jobs through one local child
/// process (`workers: 1` serializes the merge trivially).
fn baseline(jobs: Vec<Job>) -> (String, CampaignReport) {
    let transport = ProcessTransport::with_binary(binary());
    let cfg = ShardConfig {
        workers: 1,
        child_workers: 1,
        deterministic: true,
        ..ShardConfig::default()
    };
    let mut out = Vec::new();
    let report = shard_campaign(jobs, &cfg, &transport, &mut out).expect("baseline run");
    (String::from_utf8(out).expect("utf8"), report)
}

/// The fleet-side pool sizing every test uses: one connection per
/// daemon, stealing on (as `shard --hosts` always does).
fn fleet_cfg(workers: usize) -> ShardConfig {
    ShardConfig {
        workers,
        child_workers: 1,
        deterministic: true,
        steal: true,
        job_timeout_ms: 10_000,
        max_spawns: 16,
        ..ShardConfig::default()
    }
}

/// A loopback topology with probe and backoff knobs tightened to test
/// timescales (a partition must be declared dead in ~0.4 s, not 3 s).
fn short_probe_topo(addrs: &[String]) -> FleetTopology {
    FleetTopology {
        probe_interval_ms: 100,
        probe_deadline_ms: 400,
        dial_base_ms: 5,
        retry_base_ms: 5,
        ..FleetTopology::loopback(addrs)
    }
}

fn run_fleet(
    jobs: Vec<Job>,
    cfg: &ShardConfig,
    transport: &TcpTransport,
) -> (String, CampaignReport) {
    let mut out = Vec::new();
    let report = shard_campaign(jobs, cfg, transport, &mut out).expect("fleet run");
    (String::from_utf8(out).expect("utf8"), report)
}

#[test]
fn fleet_matches_single_process_byte_for_byte() {
    let (d1, d2) = (spawn_daemon(), spawn_daemon());
    let work = jobs(6, 10);
    let (want_bytes, want_report) = baseline(work.clone());

    let topo = FleetTopology::loopback(&[d1.addr.clone(), d2.addr.clone()]);
    let transport = TcpTransport::new(topo).expect("valid topology");
    let (got_bytes, got_report) = run_fleet(work, &fleet_cfg(2), &transport);

    assert_eq!(got_bytes, want_bytes, "fleet bytes must match the single-process run");
    assert_eq!(got_report, want_report);

    // the per-host counter surface covers the whole campaign: every job
    // resolved on some host (stolen duplicates may resolve twice), and
    // both daemons were dialed
    let stats = transport.stats();
    let resolved: u64 =
        (0..2).map(|h| stats.host(h).jobs.load(Ordering::SeqCst)).sum();
    assert!(resolved >= 6, "per-host job counters must cover the campaign: {resolved}");
    let dials: u64 = (0..2).map(|h| stats.host(h).dials.load(Ordering::SeqCst)).sum();
    assert!(dials >= 2, "both hosts must have been dialed: {dials}");
    let frame = stats.frame().encode();
    for key in ["jobs", "steals", "reconnects", "quarantines", "dials", "retries"] {
        assert!(frame.contains(key), "stats frame must carry '{key}': {frame}");
    }
}

#[test]
fn killed_daemon_mid_campaign_keeps_bytes() {
    let d1 = spawn_daemon();
    let mut d2 = spawn_daemon();
    let work = jobs(8, 60);
    let (want_bytes, want_report) = baseline(work.clone());

    let topo = short_probe_topo(&[d1.addr.clone(), d2.addr.clone()]);
    let transport = TcpTransport::new(topo).expect("valid topology");
    // fell the second daemon while the campaign is (very likely) still
    // in flight; its jobs must requeue onto the survivor
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(100));
        let _ = d2.child.kill();
        let _ = d2.child.wait();
        d2
    });
    let (got_bytes, got_report) = run_fleet(work, &fleet_cfg(2), &transport);
    let _d2 = killer.join().expect("killer thread");

    assert_eq!(got_bytes, want_bytes, "a dead daemon may cost time, never content");
    assert_eq!(got_report, want_report);
}

#[test]
fn disconnect_chaos_reconnects_and_keeps_bytes() {
    let (d1, d2) = (spawn_daemon(), spawn_daemon());
    let work = jobs(8, 20);
    let (want_bytes, want_report) = baseline(work.clone());

    // both connections drop mid-stream: the pool is forced to respawn,
    // which re-enters the transport and redials (fleet chaos indexes
    // are HOST indexes, and fault frames persist across reconnects, so
    // each disconnect fires exactly once)
    let topo = short_probe_topo(&[d1.addr.clone(), d2.addr.clone()]);
    let transport = TcpTransport::new(topo)
        .expect("valid topology")
        .with_chaos(ChaosPlan::parse("0:disconnect@1;1:disconnect@2").expect("chaos spec"));
    let (got_bytes, got_report) = run_fleet(work, &fleet_cfg(2), &transport);

    assert_eq!(got_bytes, want_bytes, "bytes must survive dropped connections");
    assert_eq!(got_report, want_report);
    let reconnects: u64 = (0..2)
        .map(|h| transport.stats().host(h).reconnects.load(Ordering::SeqCst))
        .sum();
    assert!(reconnects >= 1, "a redial after both drops must be counted: {reconnects}");
}

#[test]
fn seeded_partition_and_slow_host_chaos_keep_bytes() {
    let (d1, d2) = (spawn_daemon(), spawn_daemon());
    let work = jobs(8, 20);
    let (want_bytes, want_report) = baseline(work.clone());

    // a seeded schedule places one partition (silent open socket — only
    // the probe deadline can catch it) and one persistently slow host
    let topo = short_probe_topo(&[d1.addr.clone(), d2.addr.clone()]);
    let transport = TcpTransport::new(topo).expect("valid topology").with_chaos(
        ChaosPlan::parse("seed=11,launches=2,frames=4,partition=1,slow=1").expect("chaos spec"),
    );
    let (got_bytes, got_report) = run_fleet(work, &fleet_cfg(2), &transport);

    assert_eq!(got_bytes, want_bytes, "bytes must survive partitions and slow hosts");
    assert_eq!(got_report, want_report);
}

#[test]
fn quarantined_host_yields_partial_report_that_round_trips() {
    let d1 = spawn_daemon();
    // one host, zero tolerance: the first dropped connection quarantines
    // it, and with no survivors the poisoned jobs must settle into an
    // explicit partial report — not a hang, not silently wrong bytes
    let topo = FleetTopology {
        failure_budget: 1,
        dial_attempts: 1,
        ..FleetTopology::loopback(&[d1.addr.clone()])
    };
    let transport = TcpTransport::new(topo)
        .expect("valid topology")
        .with_chaos(ChaosPlan::parse("0:disconnect@0").expect("chaos spec"));
    let cfg = ShardConfig { max_worker_kills: 1, ..fleet_cfg(1) };

    let mut out = Vec::new();
    let report =
        shard_campaign(jobs(2, 10), &cfg, &transport, &mut out).expect("partial, not an error");
    assert_eq!(report.incomplete, 2, "both in-flight jobs were poisoned: {report:?}");
    assert_eq!(report.quarantined.len(), 2);
    assert_eq!(report.total_jobs, 0);
    assert_eq!(
        transport.stats().host(0).quarantines.load(Ordering::SeqCst),
        1,
        "the host itself must be quarantined"
    );

    // the emitted stream is whole: one ordered error line per poisoned
    // job, then the merged summary
    let text = String::from_utf8(out).expect("utf8");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "2 error lines + summary:\n{text}");
    for line in &lines[..2] {
        let v = JsonValue::parse(line).expect("frame parses");
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false), "{line}");
    }
    assert!(JsonValue::parse(lines[2]).expect("summary parses").get("summary").is_some());

    // and the partial report survives the JSON codec unchanged
    let round = json::report_from_json(&json::report_to_json(&report)).expect("codec");
    assert_eq!(round, report, "quarantined partial reports must round-trip");
}

// ---------------------------------------------------------------------------
// GEMM over the fleet (PR 10: typed band items + content-addressed operands)
// ---------------------------------------------------------------------------

fn gemm_session() -> Session {
    SessionBuilder::new()
        .arch(Arch::Turing)
        .instruction("HMMA.1688.F32.F16")
        .build()
        .expect("registry instruction resolves")
}

fn random_mats(
    rng: &mut Rng,
    m: usize,
    n: usize,
    k: usize,
    fmts: MmaFormats,
) -> (BitMatrix, BitMatrix, BitMatrix) {
    let mut a = BitMatrix::zeros(m, k, fmts.a);
    let mut b = BitMatrix::zeros(k, n, fmts.b);
    let mut c = BitMatrix::zeros(m, n, fmts.c);
    for v in a.data.iter_mut() {
        *v = fmts.a.from_f64(rng.normal());
    }
    for v in b.data.iter_mut() {
        *v = fmts.b.from_f64(rng.normal());
    }
    for v in c.data.iter_mut() {
        *v = fmts.c.from_f64(rng.normal());
    }
    (a, b, c)
}

#[test]
fn fleet_gemm_bit_identical_to_in_process() {
    let (d1, d2) = (spawn_daemon(), spawn_daemon());
    let s = gemm_session();
    let mut rng = Rng::new(0xF1EE7);
    let (a, b, c) = random_mats(&mut rng, 64, 32, 32, s.formats());

    let topo = short_probe_topo(&[d1.addr.clone(), d2.addr.clone()]);
    let transport = TcpTransport::new(topo).expect("valid topology");
    let got = s.shard_gemm(&a, &b, &c, &fleet_cfg(2), &transport).expect("fleet gemm");
    let want =
        TiledGemm::from_model(s.model().clone()).try_execute(&a, &b, &c).expect("in-process ref");
    assert_eq!(got.data, want.data, "fleet GEMM must be bit-identical to the in-process engine");
    assert_eq!((got.rows, got.cols, got.fmt), (want.rows, want.cols, want.fmt));

    // band replies count as resolved work on the per-host surface
    let stats = transport.stats();
    let resolved: u64 = (0..2).map(|h| stats.host(h).jobs.load(Ordering::SeqCst)).sum();
    assert!(resolved >= 1, "band replies must count as resolved work items: {resolved}");
}

#[test]
fn killed_daemon_mid_gemm_keeps_bits() {
    let d1 = spawn_daemon();
    let mut d2 = spawn_daemon();
    let s = gemm_session();
    let mut rng = Rng::new(0xF1EE8);
    let (a, b, c) = random_mats(&mut rng, 128, 64, 64, s.formats());

    let topo = short_probe_topo(&[d1.addr.clone(), d2.addr.clone()]);
    let transport = TcpTransport::new(topo).expect("valid topology");
    // fell the second daemon while bands are (very likely) in flight:
    // its bands requeue onto the survivor, which re-receives the shared
    // B operand through the content-addressed publish path
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(100));
        let _ = d2.child.kill();
        let _ = d2.child.wait();
        d2
    });
    let got = s.shard_gemm(&a, &b, &c, &fleet_cfg(2), &transport).expect("fleet gemm survives");
    let _d2 = killer.join().expect("killer thread");
    let want =
        TiledGemm::from_model(s.model().clone()).try_execute(&a, &b, &c).expect("in-process ref");
    assert_eq!(got.data, want.data, "a dead daemon may cost time, never bits");
}
