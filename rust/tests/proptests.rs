//! Property-based tests over randomized inputs (the offline image ships
//! no proptest crate; cases are generated with the crate's deterministic
//! RNG, shrink-free but seeded and reproducible).
//!
//! Invariants covered:
//! - format encode/decode round-trips for every format and random bits;
//! - Φ_FMA equals the host fused chain on FP32/FP64;
//! - T-FDPA truncation monotonicity (larger F never increases |error|
//!   for RZ outputs on positive-only inputs);
//! - T-FDPA error bound (Table 9);
//! - symmetric models negate cleanly; RD models don't (statistically);
//! - Kulisch exactness against i128 arithmetic on small inputs;
//! - zero-sign convention consistency between ops.

use mma_sim::fixedpoint::Kulisch;
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::{MmaFormats, MmaInterface};
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::ops::{e_fdpa, flush_subnormal_input, fma, ftz_add, ftz_mul, t_fdpa, TFdpaCfg};
use mma_sim::util::Rng;

const CASES: usize = 4000;

#[test]
fn prop_format_roundtrip_all_formats() {
    let mut rng = Rng::new(101);
    for fmt in Format::ALL {
        for _ in 0..CASES / 10 {
            let bits = rng.bits(fmt.width());
            let d = fmt.decode(bits);
            if d.is_nan() {
                continue;
            }
            let v = fmt.to_f64(bits);
            assert_eq!(fmt.from_f64(v), bits, "{fmt:?} {bits:#x} {v}");
        }
    }
}

#[test]
fn prop_fma_matches_host() {
    let mut rng = Rng::new(103);
    for _ in 0..CASES {
        let a = f32::from_bits(rng.next_u32());
        let b = f32::from_bits(rng.next_u32());
        let c = f32::from_bits(rng.next_u32());
        let got = fma(
            Format::Fp32,
            a.to_bits() as u64,
            b.to_bits() as u64,
            c.to_bits() as u64,
        );
        let want = a.mul_add(b, c);
        if want.is_nan() {
            assert!(f32::from_bits(got as u32).is_nan());
        } else {
            assert_eq!(got as u32, want.to_bits(), "{a} {b} {c}");
        }
    }
}

#[test]
fn prop_e_fdpa_error_is_half_ulp() {
    // E-FDPA = RNE(exact): error vs exact f64 recomputation <= 0.5 ulp
    let mut rng = Rng::new(107);
    for _ in 0..CASES / 4 {
        let a: Vec<u64> = (0..4).map(|_| Format::Fp16.from_f64(rng.normal())).collect();
        let b: Vec<u64> = (0..4).map(|_| Format::Fp16.from_f64(rng.normal())).collect();
        let c = Format::Fp32.from_f64(rng.normal());
        let out = e_fdpa(Format::Fp16, &a, &b, c);
        let got = Format::Fp32.to_f64(out);
        let exact: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| Format::Fp16.to_f64(x) * Format::Fp16.to_f64(y))
            .sum::<f64>()
            + Format::Fp32.to_f64(c);
        let ulp = 2f64.powi((exact.abs().log2().floor() as i32).max(-126) - 23);
        assert!(
            (got - exact).abs() <= 0.5 * ulp + 1e-300,
            "{got} vs {exact} (ulp {ulp})"
        );
    }
}

#[test]
fn prop_tfdpa_more_precision_is_no_worse_on_positive_inputs() {
    // With all-positive summands (no cancellation), increasing F can only
    // keep more of the tail: |d_F25 - exact| <= |d_F13 - exact|.
    let mut rng = Rng::new(109);
    for _ in 0..CASES / 8 {
        let a: Vec<u64> =
            (0..8).map(|_| Format::Fp16.from_f64(rng.uniform() * 8.0 + 0.001)).collect();
        let b: Vec<u64> =
            (0..8).map(|_| Format::Fp16.from_f64(rng.uniform() * 8.0 + 0.001)).collect();
        let c = Format::Fp32.from_f64(rng.uniform());
        let exact: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| Format::Fp16.to_f64(x) * Format::Fp16.to_f64(y))
            .sum::<f64>()
            + Format::Fp32.to_f64(c);
        let lo = t_fdpa(Format::Fp16, &a, &b, c, TFdpaCfg { f: 13, rho: Rho::RzFp32 });
        let hi = t_fdpa(Format::Fp16, &a, &b, c, TFdpaCfg { f: 25, rho: Rho::RzFp32 });
        let e_lo = (Format::Fp32.to_f64(lo) - exact).abs();
        let e_hi = (Format::Fp32.to_f64(hi) - exact).abs();
        assert!(e_hi <= e_lo + 1e-12, "F=25 err {e_hi} > F=13 err {e_lo}");
    }
}

#[test]
fn prop_tfdpa_error_bound_table9() {
    let mut rng = Rng::new(113);
    let l = 16usize;
    let f = 25i32;
    for _ in 0..CASES / 8 {
        let a: Vec<u64> = (0..l).map(|_| Format::Fp16.from_f64(rng.dnn_mix())).collect();
        let b: Vec<u64> = (0..l).map(|_| Format::Fp16.from_f64(rng.normal())).collect();
        let c = Format::Fp32.from_f64(rng.normal());
        let out = t_fdpa(Format::Fp16, &a, &b, c, TFdpaCfg { f, rho: Rho::RzFp32 });
        let got = Format::Fp32.to_f64(out);
        let prods: Vec<f64> = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| Format::Fp16.to_f64(x) * Format::Fp16.to_f64(y))
            .collect();
        let exact: f64 = prods.iter().sum::<f64>() + Format::Fp32.to_f64(c);
        let emax_val = prods
            .iter()
            .map(|p| p.abs())
            .fold(Format::Fp32.to_f64(c).abs(), f64::max);
        if emax_val == 0.0 {
            continue;
        }
        let emax = emax_val.log2().floor() as i32 + 2; // nominal exp can exceed log2
        let bound = (l as f64 + 1.0) * 2f64.powi(emax - f)
            + 2f64.powi((got.abs().log2().floor() as i32).max(-126) - 22);
        assert!(
            (got - exact).abs() <= bound,
            "err {} bound {bound} (emax {emax})",
            (got - exact).abs()
        );
    }
}

#[test]
fn prop_symmetric_models_negate_cleanly() {
    let mut rng = Rng::new(127);
    let fmts = MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 };
    for spec in [
        ModelSpec::TFdpa { l_max: 8, f: 24, rho: Rho::RzFp32 },
        ModelSpec::EFdpa { l: 4 },
        ModelSpec::FtzAddMul { p: 2 },
    ] {
        let model = MmaModel::new("sym", (4, 4, 8), fmts, spec);
        for t in 0..60 {
            let (a, b, c) = mma_sim::clfp::random_inputs(&mut rng, &model, t);
            let d1 = model.execute(&a, &b, &c, None);
            let d2 = model.execute(&a.negated(), &b, &c.negated(), None);
            for (x, y) in d1.data.iter().zip(d2.data.iter()) {
                let dx = Format::Fp32.decode(*x);
                if dx.is_nan() {
                    continue;
                }
                assert_eq!(*x ^ (1 << 31), *y, "{spec:?}");
            }
        }
    }
}

#[test]
fn prop_is_symmetric_specs_negate_bitwise() {
    // Every ModelSpec classified symmetric must satisfy
    // Φ(-A, B, -C) = -Φ(A, B, C) bit-for-bit (paper §6.2.4), modulo the
    // shared exact-zero convention (cancellation yields +0 in both
    // directions) and NaN payloads. Probed at the dot-product level with
    // unit scales for the scaled families.
    let mut rng = Rng::new(139);
    let cases: &[(ModelSpec, Format, usize)] = &[
        (ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 }, Format::Fp16, 32),
        (ModelSpec::TFdpa { l_max: 8, f: 24, rho: Rho::RneFp16 }, Format::Fp16, 16),
        (ModelSpec::EFdpa { l: 4 }, Format::Fp16, 16),
        (ModelSpec::FtzAddMul { p: 2 }, Format::Bf16, 16),
        (ModelSpec::FtzAddMul { p: 4 }, Format::Fp16, 16),
        (ModelSpec::FmaChain, Format::Fp32, 8),
        (
            ModelSpec::StFdpa { l_max: 32, f: 25, rho: Rho::RzFp32, kblock: 32 },
            Format::Fp8E4M3,
            32,
        ),
        (
            ModelSpec::GstFdpa {
                l: 64,
                g: 16,
                f: 35,
                rho: Rho::RzFp32,
                kblock: 16,
                scale_fmt: Format::E8M0,
            },
            Format::Fp4E2M1,
            64,
        ),
    ];
    for &(spec, in_fmt, k) in cases {
        assert!(spec.is_symmetric(), "{spec:?} must be classified symmetric");
        let out_fmt = match spec {
            ModelSpec::TFdpa { rho, .. } => rho.output_format(),
            _ => Format::Fp32,
        };
        let fmts = MmaFormats { a: in_fmt, b: in_fmt, c: out_fmt, d: out_fmt };
        let model = MmaModel::new("sym", (1, 1, k), fmts, spec);
        let a_sign = 1u64 << (in_fmt.width() - 1);
        let d_sign = 1u64 << (out_fmt.width() - 1);
        for _ in 0..200 {
            let a: Vec<u64> = (0..k).map(|_| rng.bits(in_fmt.width())).collect();
            let b: Vec<u64> = (0..k).map(|_| rng.bits(in_fmt.width())).collect();
            let c = rng.bits(out_fmt.width());
            let na: Vec<u64> = a.iter().map(|&x| x ^ a_sign).collect();
            let nc = c ^ d_sign;
            let d1 = model.probe(&a, &b, c);
            let d2 = model.probe(&na, &b, nc);
            let v1 = out_fmt.decode(d1);
            let v2 = out_fmt.decode(d2);
            if v1.is_nan() || v2.is_nan() {
                assert_eq!(v1.is_nan(), v2.is_nan(), "{spec:?}: NaN asymmetry");
                continue;
            }
            if v1.is_zero() && v2.is_zero() {
                continue; // exact-zero sign convention is direction-independent
            }
            assert_eq!(d1 ^ d_sign, d2, "{spec:?}: Φ(-A,B,-C) != -Φ(A,B,C)");
        }
    }
}

/// Explicit FTZ-AddMul reference: P-chunked products with pairwise
/// summation (balanced for a full P=4 chunk, left-to-right for ragged
/// tails), sequentially FTZ-accumulated — Algorithm 2 spelled out.
fn ftz_dpa_reference(fmt: Format, a: &[u64], b: &[u64], c: u64, p: usize) -> u64 {
    let mut d = flush_subnormal_input(Format::Fp32, c);
    for (ca, cb) in a.chunks(p).zip(b.chunks(p)) {
        let prods: Vec<u64> = ca
            .iter()
            .zip(cb.iter())
            .map(|(&x, &y)| {
                ftz_mul(fmt, flush_subnormal_input(fmt, x), flush_subnormal_input(fmt, y))
            })
            .collect();
        let s = match prods.len() {
            1 => prods[0],
            2 => ftz_add(prods[0], prods[1]),
            4 => ftz_add(ftz_add(prods[0], prods[1]), ftz_add(prods[2], prods[3])),
            _ => {
                let mut s = ftz_add(prods[0], prods[1]);
                for &q in &prods[2..] {
                    s = ftz_add(s, q);
                }
                s
            }
        };
        d = ftz_add(d, s);
    }
    d
}

#[test]
fn prop_ftz_ragged_tails_match_pairwise_reference() {
    // k % p ∈ {1, 2, 3}: the tail chunk takes the short summation paths.
    let mut rng = Rng::new(149);
    let fmts =
        MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 };
    for (p, ks) in [(4usize, [5usize, 6, 7, 13]), (2, [3, 5, 7, 9])] {
        for &k in &ks {
            let model = MmaModel::new("ftz-ragged", (1, 1, k), fmts, ModelSpec::FtzAddMul { p });
            for _ in 0..300 {
                let a: Vec<u64> = (0..k).map(|_| rng.bits(16)).collect();
                let b: Vec<u64> = (0..k).map(|_| rng.bits(16)).collect();
                let c = rng.bits(32);
                let got = model.probe(&a, &b, c);
                let want = ftz_dpa_reference(Format::Fp16, &a, &b, c, p);
                assert_eq!(got, want, "p={p} k={k}");
            }
        }
    }
}

#[test]
fn prop_kulisch_matches_i128_on_small_ranges() {
    let mut rng = Rng::new(131);
    for _ in 0..CASES / 4 {
        let mut acc = Kulisch::<6>::new(-64);
        let mut reference: i128 = 0; // in units of 2^-64 (the window LSB)
        for _ in 0..8 {
            let mag = rng.bits(30) as u128;
            let exp = (rng.below(40) as i32) - 32; // [-32, 8)
            let neg = rng.below(2) == 1;
            acc.add(neg, mag, exp);
            let shifted = (mag as i128) << (exp + 64);
            reference += if neg { -shifted } else { shifted };
        }
        let (neg, mag, lsb) = acc.to_sign_mag();
        // lsb >= -64 by construction; express got in the same 2^-64 units
        let got = if neg { -(mag as i128) } else { mag as i128 } << (lsb + 64);
        assert_eq!(got, reference);
    }
}

#[test]
fn prop_zero_sign_convention_shared() {
    // cancellation -> +0 across fused ops; all-negative-zeros -> -0
    let mut rng = Rng::new(137);
    for _ in 0..CASES / 20 {
        let x = rng.normal().abs() + 0.5;
        let a = [Format::Fp16.from_f64(x), Format::Fp16.from_f64(-x)];
        let b = [Format::Fp16.from_f64(1.0), Format::Fp16.from_f64(1.0)];
        let t = t_fdpa(Format::Fp16, &a, &b, 0, TFdpaCfg { f: 24, rho: Rho::RzFp32 });
        let e = e_fdpa(Format::Fp16, &a, &b, 0);
        assert_eq!(t, 0, "T-FDPA cancellation is +0");
        assert_eq!(e, 0, "E-FDPA cancellation is +0");
    }
    let neg0 = [0x8000u64, 0x8000];
    let pos1 = [Format::Fp16.from_f64(1.0), Format::Fp16.from_f64(1.0)];
    let t = t_fdpa(Format::Fp16, &neg0, &pos1, 0x8000_0000, TFdpaCfg { f: 24, rho: Rho::RzFp32 });
    assert_eq!(t, 0x8000_0000, "all-negative-zero inputs give -0");
}
