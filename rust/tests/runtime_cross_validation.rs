//! Cross-validation: the Rust golden models must agree bit-for-bit with
//! the AOT-compiled Pallas kernels executed under PJRT — the closed loop
//! between the two independent implementations of the paper's algorithms.

use mma_sim::interface::{BitMatrix, MmaInterface};
use mma_sim::runtime::{artifacts_dir, model_for_artifact, read_manifest, Runtime};
use mma_sim::util::Rng;

fn random_bits(rng: &mut Rng, rows: usize, cols: usize, fmt: mma_sim::Format) -> BitMatrix {
    let mut m = BitMatrix::zeros(rows, cols, fmt);
    for v in m.data.iter_mut() {
        *v = rng.bits(fmt.width());
    }
    m
}

#[test]
fn rust_models_match_pjrt_artifacts_bit_for_bit() {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return;
        }
    };
    let metas = read_manifest(&dir).expect("manifest");
    let mut rng = Rng::new(0xA0_7E57);
    let mut total = 0usize;
    for meta in metas.iter().filter(|m| m.kind == "tfdpa" || m.kind == "ftz") {
        let pjrt = rt.load_mma(meta).expect("load artifact");
        let model = model_for_artifact(meta).expect("model");
        let (m, n, k) = pjrt.shape();
        let fmts = pjrt.formats();
        for trial in 0..20 {
            let a = random_bits(&mut rng, m, k, fmts.a);
            let b = random_bits(&mut rng, k, n, fmts.b);
            let c = random_bits(&mut rng, m, n, fmts.c);
            let want = model.execute(&a, &b, &c, None);
            let got = pjrt.execute(&a, &b, &c, None);
            assert_eq!(
                got.data, want.data,
                "artifact {} trial {trial} diverges from Rust model",
                meta.name
            );
            total += m * n;
        }
    }
    assert!(total > 0, "no artifacts validated");
    eprintln!("cross-validated {total} output elements bit-for-bit");
}
