//! Coordinator service integration: campaigns over many pairs, queue
//! backpressure under slow consumers, and PJRT-backed verification.

use std::sync::Arc;

use mma_sim::coordinator::{Coordinator, Job, VerifyPair};
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::MmaFormats;
use mma_sim::isa;
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::runtime::{artifacts_dir, model_for_artifact, read_manifest, Runtime};

fn fmts16() -> MmaFormats {
    MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 }
}

#[test]
fn campaign_across_whole_registry_self_pairs() {
    let pairs: Vec<VerifyPair> = isa::registry()
        .into_iter()
        .filter(|i| i.m * i.n <= 256)
        .map(|i| VerifyPair {
            name: format!("{} {}", i.arch.target(), i.name),
            dut: Arc::new(i.model()),
            golden: Arc::new(i.model()),
        })
        .collect();
    assert!(pairs.len() >= 15);
    let n_pairs = pairs.len();
    let coord = Coordinator::new(pairs, 8, 16);
    let report = coord.run_campaign(2, 12, 5).unwrap();
    assert_eq!(report.total_tests, 2 * 12 * n_pairs);
    assert_eq!(report.total_mismatches, 0, "{}", report.render());
    coord.shutdown();
}

#[test]
fn manual_submission_and_collection() {
    let pair = VerifyPair {
        name: "x".into(),
        dut: Arc::new(MmaModel::new(
            "d",
            (4, 4, 8),
            fmts16(),
            ModelSpec::TFdpa { l_max: 8, f: 24, rho: Rho::RzFp32 },
        )),
        golden: Arc::new(MmaModel::new(
            "g",
            (4, 4, 8),
            fmts16(),
            ModelSpec::TFdpa { l_max: 8, f: 24, rho: Rho::RzFp32 },
        )),
    };
    let coord = Coordinator::new(vec![pair], 2, 2);
    for id in 0..6 {
        coord.submit(Job { id, pair: "x".into(), batch: 10, seed: id }).unwrap();
    }
    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..6 {
        let out = coord.next_outcome().unwrap();
        assert_eq!(out.tests, 10);
        seen.insert(out.id);
    }
    assert_eq!(seen.len(), 6, "every job must complete exactly once");
    coord.shutdown();
}

#[test]
fn unknown_pair_yields_empty_outcome() {
    let pair = VerifyPair {
        name: "known".into(),
        dut: Arc::new(MmaModel::new(
            "d",
            (4, 4, 8),
            fmts16(),
            ModelSpec::EFdpa { l: 4 },
        )),
        golden: Arc::new(MmaModel::new(
            "g",
            (4, 4, 8),
            fmts16(),
            ModelSpec::EFdpa { l: 4 },
        )),
    };
    let coord = Coordinator::new(vec![pair], 1, 2);
    coord.submit(Job { id: 1, pair: "missing".into(), batch: 10, seed: 3 }).unwrap();
    let out = coord.next_outcome().unwrap();
    assert_eq!(out.tests, 0, "unroutable job completes with zero tests");
    coord.shutdown();
}

#[test]
fn pjrt_campaign_is_clean() {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return;
        }
    };
    let mut pairs = Vec::new();
    for meta in read_manifest(&dir).unwrap() {
        if meta.kind != "tfdpa" && meta.kind != "ftz" {
            continue;
        }
        pairs.push(VerifyPair {
            name: meta.name.clone(),
            dut: Arc::new(rt.load_mma(&meta).unwrap()),
            golden: Arc::new(model_for_artifact(&meta).unwrap()),
        });
    }
    let n = pairs.len();
    assert!(n >= 8, "all artifacts registered");
    let coord = Coordinator::new(pairs, 4, 8);
    let report = coord.run_campaign(1, 10, 77).unwrap();
    assert_eq!(report.total_tests, 10 * n);
    assert_eq!(report.total_mismatches, 0, "{}", report.render());
    coord.shutdown();
}
