//! Chaos differential suite (real processes): the same invariants as
//! `chaos_faults.rs`, but with the faults injected **inside** real
//! `mma-sim` children via their `--chaos` flag (`ProcessTransport::
//! with_chaos`) — a child that really crashes mid-protocol, really goes
//! silent while the process stays alive, and really writes garbage onto
//! its stdout pipe.

use std::time::Instant;

use mma_sim::coordinator::Job;
use mma_sim::session::faults::ChaosPlan;
use mma_sim::session::json::JsonValue;
use mma_sim::session::shard::{shard_campaign, ProcessTransport};
use mma_sim::session::ShardConfig;

fn binary() -> &'static str {
    env!("CARGO_BIN_EXE_mma-sim")
}

fn jobs(n: u64) -> Vec<Job> {
    (0..n)
        .map(|i| Job { id: i, pair: "sm70 HMMA.884.F32.F16".into(), batch: 10, seed: 40 + i })
        .collect()
}

fn clean_run(n_jobs: u64, cfg: &ShardConfig) -> (String, mma_sim::coordinator::CampaignReport) {
    let transport = ProcessTransport::with_binary(binary());
    let mut out = Vec::new();
    let report = shard_campaign(jobs(n_jobs), cfg, &transport, &mut out).unwrap();
    (String::from_utf8(out).unwrap(), report)
}

#[test]
fn child_side_chaos_is_byte_identical_to_a_clean_run() {
    // launch 0 garbles its third reply line, launch 1 crashes writing its
    // second, launch 2 (the first respawn) is merely slow — with
    // quarantine off and spawn budget to spare, every job completes and
    // the deterministic output must not move by a byte
    let cfg = ShardConfig {
        workers: 2,
        child_workers: 1,
        deterministic: true,
        max_worker_kills: 0,
        max_spawns: 16,
        ..ShardConfig::default()
    };
    let (want_text, want_report) = clean_run(6, &cfg);

    let plan = ChaosPlan::parse("0:garbage@2;1:crash@1;2:delay10@0").unwrap();
    let transport = ProcessTransport::with_binary(binary()).with_chaos(plan);
    let mut out = Vec::new();
    let report = shard_campaign(jobs(6), &cfg, &transport, &mut out).unwrap();
    assert_eq!(
        String::from_utf8(out).unwrap(),
        want_text,
        "real-process faults may cost time, never content"
    );
    assert_eq!(report, want_report);
}

#[test]
fn hung_child_process_is_retired_by_the_watchdog() {
    // launch 0 hangs (flushes, then sleeps forever — process alive, pipe
    // open, zero bytes) at its second reply frame; only the per-job reply
    // deadline can unstick the merge loop
    let cfg = ShardConfig {
        workers: 2,
        child_workers: 1,
        deterministic: true,
        job_timeout_ms: 1500,
        max_worker_kills: 0,
        max_spawns: 16,
        ..ShardConfig::default()
    };
    let plan = ChaosPlan::parse("0:hang@1").unwrap();
    let transport = ProcessTransport::with_binary(binary()).with_chaos(plan);
    let started = Instant::now();
    let mut out = Vec::new();
    let report = shard_campaign(jobs(6), &cfg, &transport, &mut out).unwrap();
    let elapsed = started.elapsed();
    assert!(elapsed.as_secs() < 60, "watchdog must fire near the 1.5 s deadline: {elapsed:?}");

    let clean_cfg = ShardConfig { job_timeout_ms: 0, ..cfg };
    let (want_text, want_report) = clean_run(6, &clean_cfg);
    assert_eq!(String::from_utf8(out).unwrap(), want_text);
    assert_eq!(report, want_report);
}

#[test]
fn crash_looping_job_is_quarantined_with_stderr_context() {
    // every launch crashes on its very first reply: the lone job fells
    // worker after worker until max_worker_kills, then must come back as
    // an explicit quarantine record — not an abort, not a livelock —
    // with the child's stderr tail (which names the injected fault)
    // quoted in the reason
    let plan = ChaosPlan::parse("0:crash@0;1:crash@0;2:crash@0;3:crash@0").unwrap();
    let transport = ProcessTransport::with_binary(binary()).with_chaos(plan);
    let cfg = ShardConfig {
        workers: 1,
        child_workers: 1,
        deterministic: true,
        max_worker_kills: 3,
        max_spawns: 8,
        ..ShardConfig::default()
    };
    let job = vec![Job { id: 0, pair: "sm70 HMMA.884.F32.F16".into(), batch: 5, seed: 7 }];
    let mut out = Vec::new();
    let report = shard_campaign(job, &cfg, &transport, &mut out).unwrap();

    assert_eq!(report.total_jobs, 0, "the poisoned job never completed");
    assert_eq!(report.incomplete, 1);
    assert_eq!(report.quarantined.len(), 1);
    let q = &report.quarantined[0];
    assert_eq!(q.id, 0);
    assert_eq!(q.pair, "sm70 HMMA.884.F32.F16");
    assert_eq!(q.kills, 3);
    assert!(q.reason.contains("felled 3 workers"), "{}", q.reason);
    assert!(q.reason.contains("[stderr:"), "stderr tail must ride along: {}", q.reason);
    assert!(q.reason.contains("chaos"), "the child's own error reaches the report: {}", q.reason);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "quarantine line + summary: {text}");
    let verdict = JsonValue::parse(lines[0]).unwrap();
    assert_eq!(verdict.get("ok").and_then(|b| b.as_bool()), Some(false));
    assert_eq!(verdict.get("quarantined").and_then(|b| b.as_bool()), Some(true));
}
