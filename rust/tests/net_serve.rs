//! End-to-end tests of the TCP service tier (`session::net`): real
//! sockets, real child worker processes, real concurrent clients.
//!
//! The contract under test is the ISSUE-8 acceptance bar: with
//! `--deterministic`, a client's reply bytes are identical whether it is
//! the only client or one of N, whether the cache is cold or warm, and
//! whether the transport is TCP or the `serve --jsonl` stdin loop — and
//! a warm re-run of an identical campaign performs zero pool
//! submissions, observable through the `{"stats":true}` frame.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::thread::JoinHandle;

use mma_sim::session::json::JsonValue;
use mma_sim::session::shard::ShardConfig;
use mma_sim::session::{serve_tcp, ApiError, NetConfig, ProcessTransport};

const PAIR_A: &str = "sm70 HMMA.884.F32.F16";
const PAIR_B: &str = "sm70 HMMA.884.F16.F16";

fn binary() -> &'static str {
    env!("CARGO_BIN_EXE_mma-sim")
}

/// Start an in-process server on an ephemeral port; children are real
/// `mma-sim serve --jsonl` processes of the test-built binary.
fn start_server(cfg: NetConfig) -> (std::net::SocketAddr, JoinHandle<Result<(), ApiError>>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().expect("local addr");
    let server = std::thread::spawn(move || {
        let transport = ProcessTransport::with_binary(binary());
        serve_tcp(listener, &cfg, &transport)
    });
    (addr, server)
}

fn small_server_cfg() -> NetConfig {
    NetConfig {
        shard: ShardConfig { workers: 1, child_workers: 2, ..ShardConfig::default() },
        queue_depth: 64,
        deterministic: true,
        cache_max: 1024,
        ..NetConfig::default()
    }
}

/// One whole client session: write `input`, half-close, read every reply
/// byte until the server closes the connection.
fn run_client(addr: std::net::SocketAddr, input: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(input.as_bytes()).expect("send jobs");
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut out = String::new();
    stream.read_to_string(&mut out).expect("read replies");
    out
}

fn shut_down(addr: std::net::SocketAddr, server: JoinHandle<Result<(), ApiError>>) {
    let text = run_client(addr, "{\"shutdown\":true}\n");
    assert!(text.contains("\"shutdown\":true"), "shutdown must be acked: {text}");
    server.join().expect("server thread").expect("clean exit");
}

/// The stdin byte-identity baseline: the same job stream through
/// `serve --jsonl --workers 1 --deterministic` in a child process.
fn stdin_baseline(input: &str) -> String {
    let mut child = Command::new(binary())
        .args(["serve", "--jsonl", "--workers", "1", "--deterministic"])
        .env("MMA_SIM_THREADS", "1")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn serve --jsonl");
    child.stdin.as_mut().expect("stdin").write_all(input.as_bytes()).expect("write jobs");
    let out = child.wait_with_output().expect("child output");
    assert!(out.status.success(), "stdin baseline failed");
    String::from_utf8(out.stdout).expect("utf8 replies")
}

fn job_stream(pair: &str, seeds: &[u64], batch: usize) -> String {
    seeds
        .iter()
        .map(|s| format!("{{\"pair\":\"{pair}\",\"batch\":{batch},\"seed\":{s}}}\n"))
        .collect()
}

/// Read the first `{"stats":...}` frame a dedicated connection gets back.
fn fetch_stats(addr: std::net::SocketAddr) -> JsonValue {
    let text = run_client(addr, "{\"stats\":true}\n");
    for line in text.lines() {
        let v = JsonValue::parse(line).expect("stats reply parses");
        if v.get("stats").is_some() {
            return v;
        }
    }
    panic!("no stats frame in: {text}");
}

fn stat(frame: &JsonValue, field: &str) -> u64 {
    frame
        .get("stats")
        .and_then(|s| s.get(field))
        .and_then(|v| v.as_u64())
        .unwrap_or_else(|| panic!("stats frame missing {field}"))
}

#[test]
fn concurrent_clients_match_serial_and_stdin_byte_for_byte() {
    let (addr, server) = start_server(small_server_cfg());
    let streams = [
        job_stream(PAIR_A, &[1, 2, 3, 4], 5),
        job_stream(PAIR_B, &[5, 6, 7], 5),
        job_stream(PAIR_A, &[8, 9], 6),
    ];

    // cold + concurrent first: three clients race their jobs into the
    // shared pool at once
    let concurrent: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> =
            streams.iter().map(|input| s.spawn(move || run_client(addr, input))).collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    // then serially, one client at a time, on the same server
    let serial: Vec<String> = streams.iter().map(|input| run_client(addr, input)).collect();

    for (i, input) in streams.iter().enumerate() {
        let baseline = stdin_baseline(input);
        assert_eq!(
            concurrent[i], baseline,
            "client {i}: concurrent TCP replies must match the stdin path byte-for-byte"
        );
        assert_eq!(
            serial[i], baseline,
            "client {i}: serial TCP replies must match the stdin path byte-for-byte"
        );
    }
    shut_down(addr, server);
}

#[test]
fn error_frames_occupy_their_request_slot() {
    let (addr, server) = start_server(small_server_cfg());
    // valid, malformed, unknown pair, valid — replies must come back in
    // exactly that order, each in its own slot
    let input = format!(
        "{}garbage line\n{{\"pair\":\"no-such-pair\",\"batch\":5,\"seed\":1}}\n{}",
        job_stream(PAIR_A, &[11], 5),
        job_stream(PAIR_A, &[12], 5),
    );
    let text = run_client(addr, &input);
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "4 replies + summary:\n{text}");

    let first = JsonValue::parse(lines[0]).unwrap();
    assert_eq!(first.get("ok").and_then(|b| b.as_bool()), Some(true), "{text}");
    let second = JsonValue::parse(lines[1]).unwrap();
    assert_eq!(second.get("ok").and_then(|b| b.as_bool()), Some(false));
    assert!(second.get("id").is_none(), "a parse failure carries no id");
    let third = JsonValue::parse(lines[2]).unwrap();
    let msg = third.get("error").and_then(|e| e.as_str()).unwrap_or_default();
    assert!(msg.contains("no-such-pair"), "{msg}");
    assert_eq!(third.get("id").and_then(|i| i.as_u64()), Some(1), "unknown pair keeps its id");
    let fourth = JsonValue::parse(lines[3]).unwrap();
    assert_eq!(fourth.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert!(JsonValue::parse(lines[4]).unwrap().get("summary").is_some());
    shut_down(addr, server);
}

#[test]
fn warm_cache_rerun_is_byte_identical_with_zero_pool_submissions() {
    let cache_dir = std::env::temp_dir().join(format!("mma-net-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);

    let cfg = NetConfig { cache_dir: Some(PathBuf::from(&cache_dir)), ..small_server_cfg() };
    let (addr, server) = start_server(cfg.clone());
    let input = job_stream(PAIR_A, &[21, 22, 23], 5);

    let cold = run_client(addr, &input);
    let after_cold = fetch_stats(addr);
    assert_eq!(stat(&after_cold, "pool_submissions"), 3, "cold run computes every job");
    assert_eq!(stat(&after_cold, "misses"), 3);

    let warm = run_client(addr, &input);
    assert_eq!(warm, cold, "a warm re-run must be byte-identical");
    let after_warm = fetch_stats(addr);
    assert!(stat(&after_warm, "hits") >= 3, "warm run must hit the cache");
    assert_eq!(
        stat(&after_warm, "pool_submissions"),
        stat(&after_cold, "pool_submissions"),
        "a warm re-run must not touch the pool"
    );
    shut_down(addr, server);

    // a fresh server over the same cache dir restarts warm: identical
    // bytes, zero pool submissions ever
    let (addr2, server2) = start_server(cfg);
    let restarted = run_client(addr2, &input);
    assert_eq!(restarted, cold, "a warm *restart* must be byte-identical too");
    let stats2 = fetch_stats(addr2);
    assert_eq!(stat(&stats2, "pool_submissions"), 0, "warm restart: all hits, no compute");
    assert_eq!(stat(&stats2, "hits"), 3);
    shut_down(addr2, server2);
    let _ = std::fs::remove_dir_all(&cache_dir);
}

#[test]
fn backpressure_returns_structured_retry_and_never_drops_the_connection() {
    // one child that hangs instead of producing its first reply, a
    // one-slot global queue, and a watchdog that quarantines the hung
    // job: the second job must be rejected with the structured retry
    // frame while the slot is held, then succeed when resubmitted
    let cfg = NetConfig {
        shard: ShardConfig {
            workers: 1,
            child_workers: 1,
            job_timeout_ms: 500,
            max_worker_kills: 1,
            ..ShardConfig::default()
        },
        queue_depth: 1,
        deterministic: true,
        cache_max: 0, // no cache: the rejection path must be exercised, not memoized
        ..NetConfig::default()
    };
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().expect("local addr");
    let server = std::thread::spawn(move || {
        let transport = ProcessTransport::with_binary(binary())
            .with_chaos(mma_sim::session::ChaosPlan::parse("0:hang@0").expect("chaos spec"));
        serve_tcp(listener, &cfg, &transport)
    });

    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = &stream;
    // job 0 occupies the single slot inside the hung child; job 1 finds
    // the queue full and must be rejected immediately
    write!(writer, "{}", job_stream(PAIR_A, &[31, 32], 5)).expect("send jobs");
    writer.flush().expect("flush");

    let mut line = String::new();
    reader.read_line(&mut line).expect("first reply");
    let first = JsonValue::parse(&line).expect("parses");
    let msg = first.get("error").and_then(|e| e.as_str()).unwrap_or_default();
    assert!(msg.contains("quarantined"), "the hung job resolves as a quarantine: {line}");
    assert_eq!(first.get("quarantined").and_then(|b| b.as_bool()), Some(true));

    line.clear();
    reader.read_line(&mut line).expect("second reply");
    let second = JsonValue::parse(&line).expect("parses");
    assert_eq!(second.get("retry").and_then(|b| b.as_bool()), Some(true), "{line}");
    assert_eq!(second.get("ok").and_then(|b| b.as_bool()), Some(false));
    assert_eq!(second.get("id").and_then(|i| i.as_u64()), Some(1));

    // the connection survived the overload: resubmit on the same socket
    // and the job completes on the respawned (sane) worker
    write!(writer, "{}", job_stream(PAIR_A, &[32], 5)).expect("resubmit");
    writer.flush().expect("flush");
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut rest = String::new();
    reader.read_to_string(&mut rest).expect("drain");
    let lines: Vec<&str> = rest.lines().collect();
    assert_eq!(lines.len(), 2, "outcome + summary:\n{rest}");
    let outcome = JsonValue::parse(lines[0]).expect("parses");
    assert_eq!(outcome.get("ok").and_then(|b| b.as_bool()), Some(true), "{rest}");
    assert!(JsonValue::parse(lines[1]).unwrap().get("summary").is_some());

    shut_down(addr, server);
}

#[test]
fn shutdown_drains_in_flight_work_without_truncating_any_reply() {
    let (addr, server) = start_server(small_server_cfg());
    // jobs and the shutdown request land in one write: every job is
    // still in flight (or queued) when the server learns it must stop
    let input = format!("{}{{\"shutdown\":true}}\n", job_stream(PAIR_A, &[41, 42, 43, 44], 6));
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(input.as_bytes()).expect("send");
    // deliberately no write-half shutdown: the drain must be triggered by
    // the shutdown request itself, not by end of input
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("read all replies");

    assert!(text.ends_with('\n'), "the reply stream must end on a frame boundary");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 6, "4 outcomes + ack + summary:\n{text}");
    for line in &lines {
        JsonValue::parse(line).unwrap_or_else(|e| panic!("truncated/corrupt frame {line}: {e}"));
    }
    for line in &lines[..4] {
        let v = JsonValue::parse(line).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
    }
    let ack = JsonValue::parse(lines[4]).unwrap();
    assert_eq!(ack.get("shutdown").and_then(|b| b.as_bool()), Some(true));
    let summary = JsonValue::parse(lines[5]).unwrap();
    let jobs = summary
        .get("summary")
        .and_then(|s| s.get("total_jobs"))
        .and_then(|v| v.as_u64());
    assert_eq!(jobs, Some(4), "every in-flight job must be finished before the summary");

    server.join().expect("server thread").expect("shutdown must exit cleanly");
}
