//! The paper's full closed loop, end-to-end: CLFP must re-derive the
//! arithmetic behavior of registry instructions treated as black boxes,
//! and of the PJRT-compiled Pallas artifacts (the silicon stand-in).

use mma_sim::clfp::{infer, ClfpConfig};
use mma_sim::formats::Rho;
use mma_sim::isa::{find, Arch};
use mma_sim::models::ModelSpec;
use mma_sim::runtime::{artifacts_dir, read_manifest, Runtime};

fn cfg(tests: usize) -> ClfpConfig {
    ClfpConfig { validate_tests: tests, seed: 0xC1F9 }
}

#[test]
fn clfp_recovers_volta() {
    let m = find(Arch::Volta, "HMMA.884.F32").unwrap().model();
    let inf = infer(&m, cfg(200));
    assert_eq!(
        inf.inferred,
        Some(ModelSpec::TFdpa { l_max: 4, f: 23, rho: Rho::RzFp32 })
    );
}

#[test]
fn clfp_recovers_ada_fp8() {
    let m = find(Arch::AdaLovelace, "QMMA.16832.F32.E4M3").unwrap().model();
    let inf = infer(&m, cfg(200));
    assert_eq!(
        inf.inferred,
        Some(ModelSpec::TFdpa { l_max: 16, f: 13, rho: Rho::RzE8M13 }),
        "survivors: {:?}",
        inf.survivors
    );
}

#[test]
fn clfp_recovers_cdna3_gtr() {
    let m = find(Arch::Cdna3, "16x16x32_fp8").unwrap().model();
    let inf = infer(&m, cfg(200));
    assert_eq!(
        inf.inferred,
        Some(ModelSpec::GtrFdpa { l_max: 16, f: 24, f2: 31 }),
        "survivors: {:?}",
        inf.survivors
    );
}

#[test]
fn clfp_recovers_cdna2_bf16_both_encodings() {
    let m = find(Arch::Cdna2, "16x16x8_bf16").unwrap().model();
    let inf = infer(&m, cfg(200));
    assert_eq!(inf.inferred, Some(ModelSpec::FtzAddMul { p: 2 }));
    let m = find(Arch::Cdna2, "16x16x16_bf16_1k").unwrap().model();
    let inf = infer(&m, cfg(200));
    assert_eq!(inf.inferred, Some(ModelSpec::FtzAddMul { p: 4 }));
}

#[test]
fn clfp_infers_pjrt_artifacts() {
    // The real closed loop: the black box is a *different implementation*
    // (JAX/Pallas under XLA). CLFP must still land on the right model.
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return;
        }
    };
    let metas = read_manifest(&dir).expect("manifest");

    let want: &[(&str, ModelSpec)] = &[
        ("volta_fp16_fp32", ModelSpec::TFdpa { l_max: 4, f: 23, rho: Rho::RzFp32 }),
        ("cdna3_fp16", ModelSpec::TrFdpa { l_max: 8, f: 24, f2: 31 }),
        ("cdna2_fp16", ModelSpec::FtzAddMul { p: 4 }),
    ];
    for (name, expect) in want {
        let meta = metas.iter().find(|m| &m.name == name).expect("artifact listed");
        let pjrt = rt.load_mma(meta).expect("load");
        // modest validation count: each PJRT execute is a full XLA launch
        let inf = infer(&pjrt, cfg(30));
        assert!(inf.independent, "{name}");
        assert_eq!(
            inf.inferred.as_ref(),
            Some(expect),
            "{name}: survivors {:?}",
            inf.survivors
        );
    }
}
