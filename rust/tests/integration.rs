//! End-to-end integration tests across modules: registry → models →
//! analysis, exercising the full matrix path rather than single dpa calls.

use mma_sim::analysis::discrepancy::{table8, table8_fp64_fp32};
use mma_sim::clfp::random_inputs;
use mma_sim::formats::Format;
use mma_sim::interface::{BitMatrix, MmaInterface};
use mma_sim::isa::{self, Arch, InputClass};
use mma_sim::util::Rng;

#[test]
fn every_registry_instruction_executes() {
    let mut rng = Rng::new(1);
    for instr in isa::registry() {
        let model = instr.model();
        let (a, b, c) = random_inputs(&mut rng, &model, 2);
        let d = model.execute(&a, &b, &c, None);
        assert_eq!(d.rows, instr.m, "{}", instr.name);
        assert_eq!(d.cols, instr.n, "{}", instr.name);
        // every output must be a valid pattern of the output format
        for &bits in &d.data {
            assert_eq!(bits & !instr.formats.d.mask(), 0, "{}", instr.name);
        }
    }
}

#[test]
fn every_instruction_handles_specials_without_panic() {
    for instr in isa::registry() {
        let model = instr.model();
        let (m, n, k) = model.shape();
        let fmts = model.formats();
        // NaN/Inf patterns where the format has them
        let mut a = BitMatrix::zeros(m, k, fmts.a);
        if let Some(nan) = fmts.a.nan_pattern() {
            a.set(0, 0, nan);
        }
        if let Some(inf) = fmts.a.inf_pattern() {
            if k > 1 {
                a.set(0, 1, inf);
            }
        }
        let b = BitMatrix::splat(k, n, fmts.b, 1.0);
        let c = BitMatrix::zeros(m, n, fmts.c);
        let d = model.execute(&a, &b, &c, None);
        if fmts.a.nan_pattern().is_some() {
            let out = fmts.d.decode(d.get(0, 0));
            assert!(out.is_nan(), "{}: NaN input must produce NaN", instr.name);
        }
    }
}

#[test]
fn symmetry_classification_matches_behavior() {
    // Φ(-A,B,-C) == -Φ(A,B,C) must hold exactly for symmetric models and
    // fail for at least one input on asymmetric ones.
    let mut rng = Rng::new(3);
    for instr in isa::registry() {
        if !instr.formats.a.has_sign() {
            continue;
        }
        let model = instr.model();
        let mut found_asym = false;
        for t in 0..12 {
            let (a, b, c) = random_inputs(&mut rng, &model, t);
            let d1 = model.execute(&a, &b, &c, None);
            let d2 = model.execute(&a.negated(), &b, &c.negated(), None);
            // compare -d1 vs d2, modulo NaN payloads and the sign of zero
            // (the exact-zero convention is +0 for cancellation in both
            // directions, so strict sign-flip equality cannot hold there)
            let diverges = d1.data.iter().zip(d2.data.iter()).any(|(&x, &y)| {
                let dx = instr.formats.d.decode(x);
                let dy = instr.formats.d.decode(y);
                if dx.is_nan() || dy.is_nan() {
                    return dx.is_nan() != dy.is_nan();
                }
                if dx.is_zero() && dy.is_zero() {
                    return false;
                }
                (x ^ (1u64 << (instr.formats.d.width() - 1))) != y
            });
            if diverges {
                found_asym = true;
                assert!(
                    !instr.spec.is_symmetric(),
                    "{}: classified symmetric but behaved asymmetrically",
                    instr.name
                );
            }
        }
        if instr.arch == Arch::Cdna3
            && matches!(instr.class, InputClass::Fp16 | InputClass::Bf16)
        {
            assert!(
                found_asym,
                "{}: CDNA3 TR-FDPA must show asymmetry within a few random MMAs",
                instr.name
            );
        }
    }
}

#[test]
fn table8_is_deterministic() {
    assert_eq!(table8(), table8());
    // and the FP64/FP32 row is exactly -0.875 everywhere
    for (name, v) in table8_fp64_fp32() {
        assert_eq!(v, -0.875, "{name}");
    }
}

#[test]
fn fp16_output_instructions_stay_in_fp16_space() {
    let mut rng = Rng::new(9);
    for instr in isa::registry().iter().filter(|i| i.formats.d == Format::Fp16) {
        let model = instr.model();
        let (a, b, c) = random_inputs(&mut rng, &model, 5);
        let d = model.execute(&a, &b, &c, None);
        for &bits in &d.data {
            assert!(bits <= 0xFFFF, "{}: FP16 output exceeds 16 bits", instr.name);
        }
    }
}

#[test]
fn mx_scaled_instructions_accept_scale_operands() {
    let mut rng = Rng::new(11);
    for instr in isa::registry()
        .iter()
        .filter(|i| matches!(i.class, InputClass::Mxfp8 | InputClass::Mxfp4 | InputClass::Nvfp4))
    {
        let model = instr.model();
        let spec = model.scale_spec().expect("MX instruction has scales");
        let (m, n, k) = model.shape();
        let (a, b, c) = random_inputs(&mut rng, &model, 2);
        let nblk = k / spec.kblock;
        let unit = match spec.fmt {
            Format::E8M0 => 127u64,
            Format::Ue4M3 => 0x38,
            _ => unreachable!(),
        };
        let sa = BitMatrix { rows: m, cols: nblk, fmt: spec.fmt, data: vec![unit; m * nblk] };
        let sb = BitMatrix { rows: nblk, cols: n, fmt: spec.fmt, data: vec![unit; nblk * n] };
        let d_none = model.execute(&a, &b, &c, None);
        let d_unit = model.execute(&a, &b, &c, Some((&sa, &sb)));
        assert_eq!(d_none.data, d_unit.data, "{}: unit scales == no scales", instr.name);
        // non-unit scale changes the result
        let mut sa2 = sa.clone();
        for v in sa2.data.iter_mut() {
            *v = match spec.fmt {
                Format::E8M0 => 131,
                _ => Format::Ue4M3.from_f64(4.0),
            };
        }
        let d_scaled = model.execute(&a, &b, &c, Some((&sa2, &sb)));
        assert_ne!(d_scaled.data, d_unit.data, "{}: scales must matter", instr.name);
    }
}
