//! Session facade contract tests: every `ApiError` variant has a
//! negative-path test proving malformed input is *rejected*, not
//! panicked on, and the JSON wire format round-trips bit-exactly for
//! every registry instruction.

use mma_sim::formats::{Format, Rho};
use mma_sim::interface::{BitMatrix, MmaCase};
use mma_sim::isa::{self, Arch};
use mma_sim::session::{json, ApiError, Session, SessionBuilder};
use mma_sim::util::Rng;

fn hopper() -> Session {
    SessionBuilder::new()
        .arch(Arch::Hopper)
        .instruction("HGMMA.64x8x16.F32.F16")
        .build()
        .unwrap()
}

fn nvfp4() -> Session {
    SessionBuilder::new()
        .arch(Arch::Blackwell)
        .instruction("UTCQMMA.SF.64x8x64.F32.NVF4")
        .build()
        .unwrap()
}

// ---------------------------------------------------------------------------
// negative paths: one test per ApiError variant
// ---------------------------------------------------------------------------

#[test]
fn unknown_arch_is_rejected() {
    let err = SessionBuilder::new().arch_named("pentium3").build().unwrap_err();
    assert!(matches!(err, ApiError::UnknownArch { .. }), "{err}");
}

#[test]
fn unknown_instruction_is_rejected() {
    let err = SessionBuilder::new()
        .arch(Arch::Volta)
        .instruction("QMMA.16832")
        .build()
        .unwrap_err();
    assert!(matches!(err, ApiError::UnknownInstruction { .. }), "{err}");
}

#[test]
fn ambiguous_instruction_lists_candidates() {
    let err = SessionBuilder::new()
        .arch(Arch::Volta)
        .instruction("HMMA.884")
        .build()
        .unwrap_err();
    match err {
        ApiError::AmbiguousInstruction { candidates, .. } => {
            assert_eq!(candidates.len(), 2, "{candidates:?}")
        }
        other => panic!("expected AmbiguousInstruction, got {other}"),
    }
}

#[test]
fn wrong_shape_is_rejected_per_operand() {
    let s = hopper();
    let good = s.random_case(1);
    let fmts = s.formats();
    let (m, n, k) = s.shape();

    let mut bad = good.clone();
    bad.a = BitMatrix::zeros(m, k + 1, fmts.a);
    match s.run(&bad).unwrap_err() {
        ApiError::ShapeMismatch { operand: "A", expected, got } => {
            assert_eq!(expected, (m, k));
            assert_eq!(got, (m, k + 1));
        }
        other => panic!("expected A ShapeMismatch, got {other}"),
    }

    let mut bad = good.clone();
    bad.b = BitMatrix::zeros(k + 1, n, fmts.b);
    assert!(matches!(s.run(&bad).unwrap_err(), ApiError::ShapeMismatch { operand: "B", .. }));

    let mut bad = good.clone();
    bad.c = BitMatrix::zeros(m + 1, n, fmts.c);
    assert!(matches!(s.run(&bad).unwrap_err(), ApiError::ShapeMismatch { operand: "C", .. }));
}

#[test]
fn wrong_format_is_rejected() {
    let s = hopper();
    let mut bad = s.random_case(2);
    bad.a.fmt = Format::Bf16; // same width, wrong format
    match s.run(&bad).unwrap_err() {
        ApiError::FormatMismatch { operand: "A", expected, got } => {
            assert_eq!(expected, Format::Fp16);
            assert_eq!(got, Format::Bf16);
        }
        other => panic!("expected FormatMismatch, got {other}"),
    }
}

#[test]
fn extra_scales_are_rejected() {
    let s = hopper();
    let mut bad = s.random_case(3);
    bad.scales = Some((
        BitMatrix::zeros(1, 1, Format::E8M0),
        BitMatrix::zeros(1, 1, Format::E8M0),
    ));
    assert!(matches!(s.run(&bad).unwrap_err(), ApiError::ScaleSpecMissing { .. }));
}

#[test]
fn missing_scales_are_rejected() {
    let s = nvfp4();
    let mut bad = s.random_case(4);
    assert!(bad.scales.is_some());
    bad.scales = None;
    assert!(matches!(s.run(&bad).unwrap_err(), ApiError::MissingScales { .. }));
}

#[test]
fn wrong_scale_shape_and_format_are_rejected() {
    let s = nvfp4();
    let good = s.random_case(5);
    let (sa, sb) = good.scales.clone().unwrap();

    let mut bad = good.clone();
    bad.scales = Some((BitMatrix::zeros(sa.rows, sa.cols + 1, sa.fmt), sb.clone()));
    assert!(matches!(
        s.run(&bad).unwrap_err(),
        ApiError::ShapeMismatch { operand: "A scales", .. }
    ));

    let mut bad = good.clone();
    bad.scales = Some((BitMatrix::zeros(sa.rows, sa.cols, Format::E8M0), sb));
    assert!(matches!(
        s.run(&bad).unwrap_err(),
        ApiError::FormatMismatch { operand: "A scales", .. }
    ));
}

#[test]
fn probe_length_and_bits_are_validated() {
    let s = hopper();
    let (_, _, k) = s.shape();
    let err = s.probe(&vec![0u64; k - 1], &vec![0u64; k], 0).unwrap_err();
    assert!(matches!(err, ApiError::LengthMismatch { expected, got, .. }
        if expected == k && got == k - 1));

    // bit 16 is outside FP16's 16-bit storage
    let mut a_row = vec![0u64; k];
    a_row[0] = 1 << 16;
    let err = s.probe(&a_row, &vec![0u64; k], 0).unwrap_err();
    assert!(matches!(err, ApiError::InvalidBits { fmt: Format::Fp16, .. }), "{err}");

    // and the happy path answers like the model
    let a_row = vec![Format::Fp16.from_f64(2.0); k];
    let b_col = vec![Format::Fp16.from_f64(0.5); k];
    let got = s.probe(&a_row, &b_col, 0).unwrap();
    assert_eq!(f32::from_bits(got as u32), k as f32);
}

#[test]
fn try_from_f64_rejects_length_mismatch() {
    let err = BitMatrix::try_from_f64(2, 2, Format::Fp16, &[1.0, 2.0, 3.0]).unwrap_err();
    assert!(matches!(err, ApiError::LengthMismatch { expected: 4, got: 3, .. }));
    assert!(BitMatrix::try_from_f64(2, 2, Format::Fp16, &[1.0; 4]).is_ok());
}

#[test]
fn try_negated_rejects_unsigned_formats() {
    let m = BitMatrix::zeros(1, 2, Format::E8M0);
    assert!(matches!(m.try_negated().unwrap_err(), ApiError::UnsignedNegate { fmt: Format::E8M0 }));
    let m = BitMatrix::from_f64(1, 2, Format::Fp16, &[1.5, -3.0]);
    assert_eq!(m.try_negated().unwrap().to_f64_vec(), vec![-1.5, 3.0]);
}

#[test]
fn unsupported_overrides_are_rejected() {
    // rounding override on a model family without ρ
    let err = SessionBuilder::new()
        .arch(Arch::Ampere)
        .instruction("DMMA.884.F64")
        .rounding(Rho::RzFp32)
        .build()
        .unwrap_err();
    assert!(matches!(err, ApiError::Unsupported { what: "rounding override", .. }), "{err}");

    // inconsistent D-format override
    let err = SessionBuilder::new()
        .arch(Arch::Hopper)
        .instruction("HGMMA.64x8x16.F32.F16")
        .d_format(Format::Fp16)
        .build()
        .unwrap_err();
    assert!(matches!(err, ApiError::Unsupported { what: "format override", .. }), "{err}");

    // gemm on a block-scaled instruction
    let s = nvfp4();
    let fmts = s.formats();
    let (m, n, k) = s.shape();
    let a = BitMatrix::zeros(m, k, fmts.a);
    let b = BitMatrix::zeros(k, n, fmts.b);
    let c = BitMatrix::zeros(m, n, fmts.c);
    assert!(matches!(s.gemm(&a, &b, &c).unwrap_err(), ApiError::Unsupported { what: "gemm", .. }));
}

#[test]
fn gemm_shape_validation() {
    let s = SessionBuilder::new()
        .arch(Arch::Turing)
        .instruction("HMMA.1688.F32.F16")
        .build()
        .unwrap();
    let fmts = s.formats();
    let (tm, tn, tk) = s.shape();
    // A rows not a multiple of the tile M
    let a = BitMatrix::zeros(tm + 1, tk, fmts.a);
    let b = BitMatrix::zeros(tk, tn, fmts.b);
    let c = BitMatrix::zeros(tm + 1, tn, fmts.c);
    assert!(matches!(s.gemm(&a, &b, &c).unwrap_err(), ApiError::ShapeMismatch { .. }));
    // inner dimensions disagree
    let a = BitMatrix::zeros(tm, tk, fmts.a);
    let b = BitMatrix::zeros(2 * tk, tn, fmts.b);
    let c = BitMatrix::zeros(tm, tn, fmts.c);
    assert!(matches!(s.gemm(&a, &b, &c).unwrap_err(), ApiError::ShapeMismatch { .. }));
    // wrong operand format
    let a = BitMatrix::zeros(tm, tk, Format::Bf16);
    let b = BitMatrix::zeros(tk, tn, fmts.b);
    let c = BitMatrix::zeros(tm, tn, fmts.c);
    assert!(matches!(s.gemm(&a, &b, &c).unwrap_err(), ApiError::FormatMismatch { .. }));
}

#[test]
fn json_errors_carry_context() {
    assert!(matches!(json::decode_case("{oops").unwrap_err(), ApiError::Json { .. }));
    assert!(matches!(
        json::decode_case(r#"{"a":1,"b":2,"c":3}"#).unwrap_err(),
        ApiError::Json { .. }
    ));
}

// ---------------------------------------------------------------------------
// JSON round-trip over the whole registry
// ---------------------------------------------------------------------------

/// Random scales (not unit) so the scale matrices round-trip non-trivially.
/// One bit below full width keeps every pattern inside the format's mask
/// and away from the all-ones NaN code points.
fn randomize_scales(case: &mut MmaCase, rng: &mut Rng) {
    if let Some((sa, sb)) = &mut case.scales {
        let w = sa.fmt.width() - 1;
        for v in sa.data.iter_mut() {
            *v = rng.bits(w);
        }
        for v in sb.data.iter_mut() {
            *v = rng.bits(w);
        }
    }
}

#[test]
fn case_and_output_round_trip_for_every_registry_instruction() {
    let mut rng = Rng::new(0x5E55);
    for instr in isa::registry() {
        let s = SessionBuilder::new()
            .arch(instr.arch)
            .instruction(instr.name)
            .build()
            .unwrap_or_else(|e| panic!("{} {}: {e}", instr.arch.target(), instr.name));
        // three cases per instruction cycles all input classes (including
        // class 3, raw bit streams: NaN/Inf patterns and high bits)
        for t in 0..3 {
            let mut case = s.random_case_with(&mut rng, t);
            randomize_scales(&mut case, &mut rng);
            let line = json::encode_case(&case);
            let back = json::decode_case(&line)
                .unwrap_or_else(|e| panic!("{}: {e}\n{line}", instr.name));
            assert_eq!(back, case, "{} case bits must round-trip", instr.name);

            let output = s.run(&case).unwrap_or_else(|e| panic!("{}: {e}", instr.name));
            let line = json::encode_run_output(&output);
            let back = json::decode_run_output(&line)
                .unwrap_or_else(|e| panic!("{}: {e}\n{line}", instr.name));
            assert_eq!(back, output, "{} output bits must round-trip", instr.name);
        }
    }
}

#[test]
fn fp64_bit_patterns_round_trip_exactly() {
    // FP64 data exercises full-width u64 patterns (above 2^53)
    let s = SessionBuilder::new()
        .arch(Arch::Ampere)
        .instruction("DMMA.884.F64")
        .build()
        .unwrap();
    let mut case = s.random_case(0xD0D0);
    case.a.data[0] = u64::MAX - 1; // a quiet-NaN-ish full-width pattern
    let back = json::decode_case(&json::encode_case(&case)).unwrap();
    assert_eq!(back.a.data[0], u64::MAX - 1);
    assert_eq!(back, case);
}

// ---------------------------------------------------------------------------
// facade vs raw model: bit-identical behavior on valid inputs
// ---------------------------------------------------------------------------

#[test]
fn facade_matches_raw_model_across_architectures() {
    let mut rng = Rng::new(0xFACE);
    for (arch, frag) in [
        (Arch::Volta, "HMMA.884.F32.F16"),
        (Arch::Cdna2, "v_mfma_f32_16x16x16_f16"),
        (Arch::Cdna3, "v_mfma_f32_16x16x32_fp8_fp8"),
    ] {
        let s = SessionBuilder::new().arch(arch).instruction(frag).build().unwrap();
        let instr = s.instruction().unwrap().clone();
        let model = instr.model();
        for t in 0..3 {
            let case = s.random_case_with(&mut rng, t);
            let got = s.run(&case).unwrap();
            let want = mma_sim::interface::MmaInterface::execute(
                &model, &case.a, &case.b, &case.c, case.scales(),
            );
            assert_eq!(got.d.data, want.data, "{frag} t={t}");
        }
    }
}
