//! Chaos differential suite (in-memory): the hardened shard pool under
//! deterministic fault injection, with thread workers standing in for
//! child processes.
//!
//! The load-bearing invariant: under any fault schedule in which every
//! job still completes, a `deterministic` run's merged output stream is
//! **byte-identical** to the fault-free run — crashes, hangs, garbage,
//! truncation, and delays may cost time, never content. The quarantine
//! test pins the complement: when a poisoned job keeps felling workers,
//! the run degrades to a partial-but-explicit report instead of aborting.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use mma_sim::coordinator::{Job, VerifyPair};
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::MmaFormats;
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::session::faults::{ChaosPlan, ChaosTransport};
use mma_sim::session::json::{self, JsonValue};
use mma_sim::session::shard::{shard_campaign, WorkerHandle, WorkerIo, WorkerRole, WorkerTransport};
use mma_sim::session::{serve_jsonl, ApiError, ServeConfig, ShardConfig};

// -- in-memory pipes + thread workers (the shard.rs unit-test pattern,
//    rebuilt on the public API) ---------------------------------------------

#[derive(Default)]
struct PipeInner {
    buf: VecDeque<u8>,
    closed: bool,
}

/// A blocking byte pipe: writes append, reads block until data or close.
#[derive(Clone, Default)]
struct Pipe(Arc<(Mutex<PipeInner>, Condvar)>);

impl Pipe {
    fn close(&self) {
        let (m, cv) = &*self.0;
        m.lock().unwrap().closed = true;
        cv.notify_all();
    }
    fn writer(&self) -> PipeWriter {
        PipeWriter(self.clone())
    }
    fn reader(&self) -> PipeReader {
        PipeReader(self.clone())
    }
}

struct PipeWriter(Pipe);

impl Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let (m, cv) = &*self.0 .0;
        let mut st = m.lock().unwrap();
        if st.closed {
            return Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "pipe closed"));
        }
        st.buf.extend(buf.iter().copied());
        cv.notify_all();
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        self.0.close();
    }
}

struct PipeReader(Pipe);

impl Read for PipeReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let (m, cv) = &*self.0 .0;
        let mut st = m.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                let n = buf.len().min(st.buf.len());
                for slot in buf.iter_mut().take(n) {
                    *slot = st.buf.pop_front().expect("buffer checked non-empty");
                }
                return Ok(n);
            }
            if st.closed {
                return Ok(0);
            }
            st = cv.wait(st).unwrap();
        }
    }
}

struct ThreadHandle {
    join: Option<std::thread::JoinHandle<()>>,
    stdin: Pipe,
    stdout: Pipe,
}

impl WorkerHandle for ThreadHandle {
    fn wait(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
    fn kill(&mut self) {
        self.stdin.close();
        self.stdout.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_pairs() -> Vec<VerifyPair> {
    let model = |f: i32| {
        MmaModel::new(
            format!("chaos-f{f}"),
            (4, 4, 8),
            MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 },
            ModelSpec::TFdpa { l_max: 8, f, rho: Rho::RzFp32 },
        )
    };
    vec![
        VerifyPair { name: "clean".into(), dut: Arc::new(model(24)), golden: Arc::new(model(24)) },
        VerifyPair { name: "faulty".into(), dut: Arc::new(model(25)), golden: Arc::new(model(24)) },
    ]
}

/// Each "child process" is a thread running the very same `serve_jsonl`
/// loop the real binary would, over in-memory pipes.
struct ThreadTransport;

impl WorkerTransport for ThreadTransport {
    fn launch(&self, role: &WorkerRole) -> Result<WorkerIo, ApiError> {
        let stdin = Pipe::default();
        let stdout = Pipe::default();
        let (child_in, child_out) = (stdin.reader(), stdout.writer());
        let workers = match role {
            WorkerRole::Campaign { workers } => *workers,
            WorkerRole::Gemm { .. } => panic!("campaign-only transport"),
        };
        let cfg = ServeConfig { workers, ..ServeConfig::default() };
        let join = std::thread::spawn(move || {
            let mut out = child_out;
            let _ = serve_jsonl(worker_pairs(), &cfg, BufReader::new(child_in), &mut out);
        });
        Ok(WorkerIo {
            input: Box::new(stdin.writer()),
            output: Box::new(stdout.reader()),
            stderr: None,
            handle: Box::new(ThreadHandle { join: Some(join), stdin, stdout }),
        })
    }
}

fn jobs(n: u64) -> Vec<Job> {
    (0..n)
        .map(|i| Job {
            id: i,
            pair: if i % 2 == 0 { "clean" } else { "faulty" }.into(),
            batch: 24,
            seed: 1000 + i,
        })
        .collect()
}

fn fault_free_baseline(n_jobs: u64) -> (String, mma_sim::coordinator::CampaignReport) {
    let cfg = ShardConfig { workers: 2, deterministic: true, ..ShardConfig::default() };
    let mut out = Vec::new();
    let report = shard_campaign(jobs(n_jobs), &cfg, &ThreadTransport, &mut out).unwrap();
    (String::from_utf8(out).unwrap(), report)
}

// -- the differential invariant ---------------------------------------------

#[test]
fn seeded_chaos_output_is_byte_identical_to_fault_free() {
    let (want_text, want_report) = fault_free_baseline(8);
    for seed in [1u64, 7, 42] {
        // crashes, hangs, garbage, truncation, and delays on a seeded
        // schedule; quarantine off and a generous spawn budget so every
        // job is guaranteed to complete eventually
        let plan = ChaosPlan::seeded(seed, 6, 12, 2, 1, 2, 1, 1);
        let inner = ThreadTransport;
        let chaotic = ChaosTransport::new(&inner, plan);
        let cfg = ShardConfig {
            workers: 2,
            deterministic: true,
            job_timeout_ms: 500, // hangs need the watchdog to resolve
            max_worker_kills: 0, // never quarantine: all jobs must finish
            max_spawns: 32,
            ..ShardConfig::default()
        };
        let mut out = Vec::new();
        let report = shard_campaign(jobs(8), &cfg, &chaotic, &mut out).unwrap();
        assert_eq!(
            String::from_utf8(out).unwrap(),
            want_text,
            "seed {seed}: faults may cost time, never content"
        );
        assert_eq!(report, want_report, "seed {seed}");
    }
}

#[test]
fn hung_worker_is_retired_within_the_deadline() {
    // launch 0 goes silent (stream open, nothing arrives) at its second
    // reply frame; the watchdog must retire it and requeue — without the
    // job timeout this schedule deadlocked the pre-hardening pool
    let plan = ChaosPlan::parse("0:hang@1").unwrap();
    let inner = ThreadTransport;
    let chaotic = ChaosTransport::new(&inner, plan);
    let cfg = ShardConfig {
        workers: 2,
        deterministic: true,
        job_timeout_ms: 400,
        max_worker_kills: 0,
        ..ShardConfig::default()
    };
    let started = Instant::now();
    let mut out = Vec::new();
    let report = shard_campaign(jobs(8), &cfg, &chaotic, &mut out).unwrap();
    let elapsed = started.elapsed();
    assert!(
        elapsed.as_secs() < 20,
        "retirement must be deadline-driven, not luck: took {elapsed:?}"
    );
    let (want_text, want_report) = fault_free_baseline(8);
    assert_eq!(String::from_utf8(out).unwrap(), want_text);
    assert_eq!(report, want_report);
}

#[test]
fn delays_do_not_trip_the_watchdog() {
    // slow-but-alive workers (50 ms on several frames) against a 5 s
    // deadline: slowness must be absorbed, not punished — the run
    // completes with nothing quarantined, and max_spawns == workers
    // leaves no budget for spurious churn if both children were ever
    // falsely retired
    let plan = ChaosPlan::parse("0:delay50@0,delay50@2;1:delay50@1").unwrap();
    let inner = ThreadTransport;
    let chaotic = ChaosTransport::new(&inner, plan);
    let cfg = ShardConfig {
        workers: 2,
        deterministic: true,
        job_timeout_ms: 5000,
        max_spawns: 2,
        ..ShardConfig::default()
    };
    let mut out = Vec::new();
    let report = shard_campaign(jobs(6), &cfg, &chaotic, &mut out).unwrap();
    assert_eq!(report.total_jobs, 6);
    assert_eq!(report.incomplete, 0);
}

// -- quarantine: graceful degradation on a poisoned job ----------------------

/// A marker only the poison job's line carries (its seed).
const POISON_MARKER: &str = "999983";

/// Wraps a worker's stdin and simulates a child that dies the moment the
/// poison job reaches it: the gate reports end-of-input *before*
/// delivering the poisoned line, so the worker exits still owing that
/// job — every single time, on every worker.
struct PoisonGate {
    inner: BufReader<PipeReader>,
    line: Vec<u8>,
    pos: usize,
    poisoned: bool,
}

impl Read for PoisonGate {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        if self.pos >= self.line.len() {
            if self.poisoned {
                return Ok(0);
            }
            let mut next = String::new();
            if self.inner.read_line(&mut next)? == 0 {
                return Ok(0);
            }
            if next.contains(POISON_MARKER) {
                self.poisoned = true;
                return Ok(0);
            }
            self.line = next.into_bytes();
            self.pos = 0;
        }
        let n = out.len().min(self.line.len() - self.pos);
        out[..n].copy_from_slice(&self.line[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

struct PoisonTransport;

impl WorkerTransport for PoisonTransport {
    fn launch(&self, role: &WorkerRole) -> Result<WorkerIo, ApiError> {
        let stdin = Pipe::default();
        let stdout = Pipe::default();
        let gate = PoisonGate {
            inner: BufReader::new(stdin.reader()),
            line: Vec::new(),
            pos: 0,
            poisoned: false,
        };
        let child_out = stdout.writer();
        let workers = match role {
            WorkerRole::Campaign { workers } => *workers,
            WorkerRole::Gemm { .. } => panic!("campaign-only transport"),
        };
        let cfg = ServeConfig { workers, ..ServeConfig::default() };
        let join = std::thread::spawn(move || {
            let mut out = child_out;
            let _ = serve_jsonl(worker_pairs(), &cfg, BufReader::new(gate), &mut out);
        });
        Ok(WorkerIo {
            input: Box::new(stdin.writer()),
            output: Box::new(stdout.reader()),
            stderr: None,
            handle: Box::new(ThreadHandle { join: Some(join), stdin, stdout }),
        })
    }
}

#[test]
fn poisoned_job_is_quarantined_into_a_partial_report() {
    let mut js = jobs(6);
    js[3].seed = 999_983; // the poison: fells every worker it reaches
    let cfg = ShardConfig {
        workers: 2,
        inflight: 1, // one job in flight per child: clean kill accounting
        deterministic: true,
        max_worker_kills: 3,
        max_spawns: 16,
        ..ShardConfig::default()
    };
    let mut out = Vec::new();
    let report = shard_campaign(js, &cfg, &PoisonTransport, &mut out).unwrap();

    // the run degraded instead of aborting: 5 of 6 jobs ran, and the
    // report says so explicitly
    assert_eq!(report.total_jobs, 5);
    assert_eq!(report.incomplete, 1);
    assert_eq!(report.quarantined.len(), 1);
    let q = &report.quarantined[0];
    assert_eq!(q.id, 3);
    assert_eq!(q.pair, "faulty");
    assert_eq!(q.kills, 3, "quarantine fires exactly at max_worker_kills");
    assert!(q.reason.contains("felled 3 workers"), "{}", q.reason);

    // the quarantine verdict is an ordered line in the merged stream,
    // exactly where job 3's outcome would have been
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 7, "5 outcomes + 1 quarantine line + summary: {text}");
    let verdict = JsonValue::parse(lines[3]).unwrap();
    assert_eq!(verdict.get("ok").and_then(|b| b.as_bool()), Some(false));
    assert_eq!(verdict.get("id").and_then(|i| i.as_u64()), Some(3));
    assert_eq!(verdict.get("quarantined").and_then(|b| b.as_bool()), Some(true));
    let msg = verdict.get("error").and_then(|e| e.as_str()).unwrap_or_default();
    assert!(msg.starts_with("job quarantined:"), "{msg}");

    // and the degraded report survives its own wire format
    let summary = JsonValue::parse(lines[6]).unwrap();
    let decoded = json::report_from_json(summary.get("summary").unwrap()).unwrap();
    assert_eq!(decoded, report);
}
