//! Bit-identity of the zero-copy strided execution core.
//!
//! MMA semantics are defined purely per dot product, so any traversal that
//! feeds the kernels the same `(a_row, b_col, c)` triples must be
//! bit-identical. These tests pin `MmaModel::execute_view_into` (strided
//! views + pretransposed B panel + hoisted kernel dispatch) against an
//! independent PR-1-style staged reference — element-wise gathers plus a
//! per-output `dpa` call — across every registry instruction, every input
//! class, random block scales, ragged K, and non-contiguous subviews.

use mma_sim::clfp::random_inputs;
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::{BitMatrix, MatMut, MmaCase, MmaFormats, MmaInterface};
use mma_sim::isa;
use mma_sim::models::{DpaScratch, MmaModel, ModelSpec};
use mma_sim::util::Rng;

/// The PR-1 execution pattern, reimplemented here so the library's view
/// path is checked against code that shares none of it: stage every A row
/// and B column with element-wise `get` loops, gather the per-output scale
/// slices, and run one `dpa` per output element.
fn staged_reference(model: &MmaModel, case: &MmaCase) -> BitMatrix {
    let (m, n, k) = model.shape();
    let mut d = BitMatrix::zeros(m, n, model.formats.d);
    let nblk = model.scale_blocks();
    let unit_scales;
    let (sa_mat, sb_mat) = match (&case.scales, model.scale_spec()) {
        (Some((sa, sb)), _) => (Some(sa), Some(sb)),
        (None, Some(spec)) => {
            // unit scales, mirroring execute_into with `scales: None`
            let unit = match spec.fmt {
                Format::E8M0 => 127,
                Format::Ue4M3 => 0x38,
                other => panic!("not a scale format: {other:?}"),
            };
            unit_scales = (
                BitMatrix { rows: m, cols: nblk, fmt: spec.fmt, data: vec![unit; m * nblk] },
                BitMatrix { rows: nblk, cols: n, fmt: spec.fmt, data: vec![unit; nblk * n] },
            );
            (Some(&unit_scales.0), Some(&unit_scales.1))
        }
        (None, None) => (None, None),
    };
    for j in 0..n {
        let bcol: Vec<u64> = (0..k).map(|kk| case.b.get(kk, j)).collect();
        let sb: Vec<u64> = sb_mat
            .map(|sb| (0..nblk).map(|r| sb.get(r, j)).collect())
            .unwrap_or_default();
        for i in 0..m {
            let arow: Vec<u64> = (0..k).map(|kk| case.a.get(i, kk)).collect();
            let sa: Vec<u64> = sa_mat
                .map(|sa| (0..nblk).map(|blk| sa.get(i, blk)).collect())
                .unwrap_or_default();
            d.set(i, j, model.dpa(&arow, &bcol, case.c.get(i, j), &sa, &sb));
        }
    }
    d
}

/// Random scale operands matching the model's block-scale spec (arbitrary
/// bit patterns: both paths must agree even on NaN/extreme scales).
fn random_scales(rng: &mut Rng, model: &MmaModel) -> Option<(BitMatrix, BitMatrix)> {
    let spec = model.scale_spec()?;
    let (m, n, _) = model.shape();
    let nblk = model.scale_blocks();
    let mut sa = BitMatrix::zeros(m, nblk, spec.fmt);
    let mut sb = BitMatrix::zeros(nblk, n, spec.fmt);
    for v in sa.data.iter_mut() {
        *v = rng.bits(spec.fmt.width());
    }
    for v in sb.data.iter_mut() {
        *v = rng.bits(spec.fmt.width());
    }
    Some((sa, sb))
}

fn run_view_path(model: &MmaModel, case: &MmaCase, scratch: &mut DpaScratch) -> BitMatrix {
    let (m, n, _) = model.shape();
    let mut d = BitMatrix::zeros(m, n, model.formats.d);
    model.execute_view_into(
        case.a.view(),
        case.b.view(),
        case.c.view(),
        case.scales(),
        d.view_mut(),
        scratch,
    );
    d
}

#[test]
fn registry_view_path_matches_staged_reference() {
    // Every instruction in the registry (every model family, both
    // vendors, scaled and unscaled), one case per input class, one shared
    // scratch so buffer reuse across differently-shaped models is
    // exercised too.
    let mut rng = Rng::new(0x51EED);
    let mut scratch = DpaScratch::default();
    for instr in isa::registry() {
        let model = instr.model();
        for t in 0..3 {
            let (a, b, c) = random_inputs(&mut rng, &model, t);
            let mut case = MmaCase::new(a, b, c);
            case.scales = random_scales(&mut rng, &model);
            let got = run_view_path(&model, &case, &mut scratch);
            let want = staged_reference(&model, &case);
            assert_eq!(
                got.data, want.data,
                "{} {} (class {t})",
                instr.arch.target(),
                instr.name
            );
        }
    }
}

#[test]
fn ragged_k_scaled_models_match_staged_reference() {
    // K not a multiple of the vector length: the final chunk spans a
    // partial group and a partial scale block (the PR-1 div_ceil fix).
    let gst = MmaModel::new(
        "gst-ragged",
        (4, 4, 40),
        MmaFormats {
            a: Format::Fp4E2M1,
            b: Format::Fp4E2M1,
            c: Format::Fp32,
            d: Format::Fp32,
        },
        ModelSpec::GstFdpa {
            l: 32,
            g: 16,
            f: 35,
            rho: Rho::RzFp32,
            kblock: 16,
            scale_fmt: Format::E8M0,
        },
    );
    // ST with K spanning several whole blocks (L == kblock per call).
    let st = MmaModel::new(
        "st-multiblock",
        (4, 4, 96),
        MmaFormats {
            a: Format::Fp8E4M3,
            b: Format::Fp8E4M3,
            c: Format::Fp32,
            d: Format::Fp32,
        },
        ModelSpec::StFdpa { l_max: 32, f: 25, rho: Rho::RzFp32, kblock: 32 },
    );
    // unscaled ragged K for the chunked FDPA families
    let tr = MmaModel::new(
        "tr-ragged",
        (4, 4, 21),
        MmaFormats {
            a: Format::Fp16,
            b: Format::Fp16,
            c: Format::Fp32,
            d: Format::Fp32,
        },
        ModelSpec::TrFdpa { l_max: 8, f: 24, f2: 31 },
    );
    let mut rng = Rng::new(0xA66ED);
    let mut scratch = DpaScratch::default();
    for model in [&gst, &st, &tr] {
        for t in 0..6 {
            let (a, b, c) = random_inputs(&mut rng, model, t);
            let mut case = MmaCase::new(a, b, c);
            case.scales = random_scales(&mut rng, model);
            let got = run_view_path(model, &case, &mut scratch);
            let want = staged_reference(model, &case);
            assert_eq!(got.data, want.data, "{} (class {})", model.name, t % 3);
        }
    }
}

#[test]
fn subview_operands_match_contiguous_execution() {
    // Operands embedded in larger matrices (surrounded by random noise)
    // and addressed through non-contiguous subviews must produce the same
    // bits as the contiguous whole-matrix run — this pins the
    // offset/row_stride arithmetic through the real execution path.
    let fmts = MmaFormats {
        a: Format::Fp16,
        b: Format::Fp16,
        c: Format::Fp32,
        d: Format::Fp32,
    };
    let specs = [
        ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 },
        ModelSpec::FtzAddMul { p: 4 },
        ModelSpec::EFdpa { l: 4 },
        ModelSpec::GtrFdpa { l_max: 16, f: 24, f2: 31 },
    ];
    let mut rng = Rng::new(0x5DB);
    for spec in specs {
        let model = MmaModel::new("sub", (8, 8, 16), fmts, spec);
        let (m, n, k) = model.shape();
        let (a, b, c) = random_inputs(&mut rng, &model, 2);
        let want = model.execute(&a, &b, &c, None);

        // embed each operand at a nonzero offset inside a larger matrix
        let mut big_a = BitMatrix::zeros(m + 3, k + 5, fmts.a);
        let mut big_b = BitMatrix::zeros(k + 2, n + 4, fmts.b);
        let mut big_c = BitMatrix::zeros(m + 1, n + 3, fmts.c);
        for v in big_a.data.iter_mut() {
            *v = rng.bits(fmts.a.width());
        }
        for v in big_b.data.iter_mut() {
            *v = rng.bits(fmts.b.width());
        }
        for v in big_c.data.iter_mut() {
            *v = rng.bits(fmts.c.width());
        }
        for i in 0..m {
            for kk in 0..k {
                big_a.set(i + 2, kk + 4, a.get(i, kk));
            }
        }
        for kk in 0..k {
            for j in 0..n {
                big_b.set(kk + 1, j + 3, b.get(kk, j));
            }
        }
        for i in 0..m {
            for j in 0..n {
                big_c.set(i, j + 2, c.get(i, j));
            }
        }

        // write D through a strided window of a larger matrix too
        let mut big_d = BitMatrix::zeros(m + 2, n + 5, fmts.d);
        let noise = 0xDEAD;
        for v in big_d.data.iter_mut() {
            *v = noise;
        }
        let mut scratch = DpaScratch::default();
        model.execute_view_into(
            big_a.subview(2, 4, m, k),
            big_b.subview(1, 3, k, n),
            big_c.subview(0, 2, m, n),
            None,
            MatMut {
                data: &mut big_d.data,
                rows: m,
                cols: n,
                row_stride: n + 5,
                offset: (n + 5) + 1, // window at (1, 1)
            },
            &mut scratch,
        );
        for i in 0..m {
            for j in 0..n {
                assert_eq!(big_d.get(i + 1, j + 1), want.get(i, j), "{spec:?} ({i},{j})");
            }
        }
        // everything outside the window is untouched
        for j in 0..n + 5 {
            assert_eq!(big_d.get(0, j), noise, "{spec:?} row 0 clobbered");
        }
        for i in 0..m + 2 {
            assert_eq!(big_d.get(i, 0), noise, "{spec:?} col 0 clobbered");
        }
    }
}
