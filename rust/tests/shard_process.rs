//! Cross-process shard integration: every test here drives real
//! `mma-sim` child processes through the shard pool, pinning the two
//! acceptance properties of the sharding subsystem — sharded GEMM is
//! bit-identical to the in-process engine, and a child that dies (the
//! kill-one-child scenario) neither loses jobs nor leaks processes.

use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};

use mma_sim::coordinator::Job;
use mma_sim::gemm::TiledGemm;
use mma_sim::interface::{BitMatrix, MmaFormats};
use mma_sim::isa::Arch;
use mma_sim::session::shard::{
    shard_campaign, ProcessTransport, WorkerHandle, WorkerIo, WorkerRole, WorkerTransport,
};
use mma_sim::session::{ApiError, CampaignConfig, SessionBuilder, ShardConfig};
use mma_sim::util::Rng;

fn binary() -> &'static str {
    env!("CARGO_BIN_EXE_mma-sim")
}

fn random_mats(
    rng: &mut Rng,
    m: usize,
    n: usize,
    k: usize,
    fmts: MmaFormats,
) -> (BitMatrix, BitMatrix, BitMatrix) {
    let mut a = BitMatrix::zeros(m, k, fmts.a);
    let mut b = BitMatrix::zeros(k, n, fmts.b);
    let mut c = BitMatrix::zeros(m, n, fmts.c);
    for v in a.data.iter_mut() {
        *v = fmts.a.from_f64(rng.normal());
    }
    for v in b.data.iter_mut() {
        *v = fmts.b.from_f64(rng.normal());
    }
    for v in c.data.iter_mut() {
        *v = fmts.c.from_f64(rng.normal());
    }
    (a, b, c)
}

#[test]
fn sharded_gemm_256_bit_identical_across_process_boundary() {
    // the acceptance case: a 256x256x256 GEMM scattered over child
    // processes must be bit-identical to TiledGemm::try_execute
    let s = SessionBuilder::new()
        .arch(Arch::Hopper)
        .instruction("HGMMA.64x8x16.F32.F16")
        .build()
        .unwrap();
    let mut rng = Rng::new(0x256);
    let (a, b, c) = random_mats(&mut rng, 256, 256, 256, s.formats());
    let transport = ProcessTransport::with_binary(binary());
    let cfg = ShardConfig {
        workers: 3,
        child_workers: 1,
        deterministic: false,
        ..ShardConfig::default()
    };
    let got = s.shard_gemm(&a, &b, &c, &cfg, &transport).unwrap();
    let want = TiledGemm::from_model(s.model().clone()).try_execute(&a, &b, &c).unwrap();
    assert_eq!(got.data, want.data, "cross-process GEMM must be bit-identical");
    assert_eq!((got.rows, got.cols, got.fmt), (want.rows, want.cols, want.fmt));
}

/// A transport whose first worker is dead on arrival: it exits with an
/// error before reading any input or writing a single protocol line —
/// the process-level kill-one-child scenario.
struct FirstChildDead {
    real: ProcessTransport,
    launches: AtomicUsize,
}

struct Reaper(std::process::Child);

impl WorkerHandle for Reaper {
    fn wait(&mut self) {
        let _ = self.0.wait();
    }
    fn kill(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

impl WorkerTransport for FirstChildDead {
    fn launch(&self, role: &WorkerRole) -> Result<WorkerIo, ApiError> {
        if self.launches.fetch_add(1, Ordering::SeqCst) > 0 {
            return self.real.launch(role);
        }
        let mut child = Command::new(binary())
            .args(["simulate", "--arch", "z80"]) // exits 1, stdout empty
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn dead-on-arrival child");
        Ok(WorkerIo {
            input: Box::new(child.stdin.take().expect("piped stdin")),
            output: Box::new(child.stdout.take().expect("piped stdout")),
            stderr: None,
            handle: Box::new(Reaper(child)),
        })
    }
}

#[test]
fn killed_child_loses_no_jobs_and_reaps_cleanly() {
    let pair = "sm70 HMMA.884.F32.F16";
    let jobs: Vec<Job> = (0..6)
        .map(|i| Job { id: i, pair: pair.into(), batch: 10, seed: 40 + i })
        .collect();
    let flaky = FirstChildDead {
        real: ProcessTransport::with_binary(binary()),
        launches: AtomicUsize::new(0),
    };
    let cfg =
        ShardConfig { workers: 2, child_workers: 1, deterministic: true, ..ShardConfig::default() };
    let mut out = Vec::new();
    let report = shard_campaign(jobs.clone(), &cfg, &flaky, &mut out).unwrap();
    assert_eq!(report.total_jobs, 6, "jobs owned by the dead child were requeued");
    assert_eq!(report.total_tests, 60);
    assert_eq!(report.total_mismatches, 0);

    // and the merged stream is byte-identical to an all-healthy run —
    // a dead child may cost time, never content
    let healthy = ProcessTransport::with_binary(binary());
    let healthy_cfg = ShardConfig { workers: 1, ..cfg };
    let mut healthy_out = Vec::new();
    let healthy_report = shard_campaign(jobs, &healthy_cfg, &healthy, &mut healthy_out).unwrap();
    assert_eq!(String::from_utf8(out).unwrap(), String::from_utf8(healthy_out).unwrap());
    assert_eq!(report, healthy_report);
    // returning at all proves the pool reaped: a leaked child would hold
    // the stdout pipe open and the merge loop would still be blocked
}

#[test]
fn session_shard_campaign_self_verifies_across_processes() {
    let s = SessionBuilder::new()
        .arch(Arch::Volta)
        .instruction("HMMA.884.F32.F16")
        .build()
        .unwrap();
    let transport = ProcessTransport::with_binary(binary());
    let cfg = CampaignConfig { workers: 2, jobs: 4, batch: 10, seed: 3 };
    let shard_cfg = ShardConfig { workers: 2, ..ShardConfig::default() };
    let mut out = Vec::new();
    let report = s.shard_campaign(&cfg, &shard_cfg, &transport, &mut out).unwrap();
    assert_eq!(report.total_jobs, 4);
    assert_eq!(report.total_tests, 40);
    assert_eq!(report.total_mismatches, 0, "self-verification must be clean");
    assert!(report.wall_micros > 0, "non-deterministic mode keeps shard timing");
}
