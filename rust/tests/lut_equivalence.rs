//! Exhaustive LUT-vs-bit-level equivalence.
//!
//! The table layer (`formats::tables`) must be bitwise invisible: for
//! every format with ≤ 16 storage bits, every one of the 2^width bit
//! patterns must produce identical `Decoded` and `to_f64` results through
//! the LUT dispatch and through the bit-level reference path; for every
//! ordered pair of ≤ 8-bit formats, the pair-product table must match
//! decode-and-multiply for all pattern pairs — including the
//! NaN/Inf/zero/subnormal code points; and for the 16-bit formats, the
//! split exponent/mantissa sub-tables (`product_split`) must reproduce
//! the decode-and-multiply product term across boundary code points and
//! randomized pairs.

use mma_sim::fixedpoint::FxTerm;
use mma_sim::formats::{tables, Format};
use mma_sim::util::Rng;

fn narrow(max_width: u32) -> impl Iterator<Item = Format> {
    Format::ALL.iter().copied().filter(move |f| f.width() <= max_width)
}

#[test]
fn lut_coverage_is_exactly_the_narrow_formats() {
    for fmt in Format::ALL {
        let is_narrow = fmt.width() <= 16;
        assert_eq!(tables::decode_lut(fmt).is_some(), is_narrow, "{fmt:?}");
        assert_eq!(tables::f64_lut(fmt).is_some(), is_narrow, "{fmt:?}");
        let has_prod = fmt.width() <= 8;
        assert_eq!(tables::product(fmt, 0, fmt, 0).is_some(), has_prod, "{fmt:?}");
    }
    // the virtual E8M13 target (22 bits) stays on the bit-level path
    assert!(tables::decode_lut(Format::E8M13).is_none());
    assert!(tables::f64_lut(Format::E8M13).is_none());
}

#[test]
fn decode_lut_matches_bit_level_for_every_pattern() {
    for fmt in narrow(16) {
        for bits in 0..=fmt.mask() {
            // `decode` dispatches through the LUT for these formats
            let lut = fmt.decode(bits);
            let reference = fmt.decode_reference(bits);
            assert_eq!(lut, reference, "{fmt:?} bits {bits:#x}");
        }
    }
}

#[test]
fn to_f64_lut_matches_bit_level_for_every_pattern() {
    for fmt in narrow(16) {
        for bits in 0..=fmt.mask() {
            let lut = fmt.to_f64(bits);
            let reference = fmt.to_f64_reference(bits);
            // bit compare: covers NaN payloads and the sign of zero
            assert_eq!(
                lut.to_bits(),
                reference.to_bits(),
                "{fmt:?} bits {bits:#x}: {lut} vs {reference}"
            );
        }
    }
}

#[test]
fn product_lut_matches_decode_and_multiply_for_all_pairs() {
    for fa in narrow(8) {
        for fb in narrow(8) {
            for a in 0..=fa.mask() {
                let da = fa.decode_reference(a);
                for b in 0..=fb.mask() {
                    let db = fb.decode_reference(b);
                    let got = tables::product(fa, a, fb, b).expect("≤8-bit pair has a table");
                    let want = FxTerm::product(
                        da.sig,
                        da.exp,
                        fa.mant_bits(),
                        da.sign,
                        db.sig,
                        db.exp,
                        fb.mant_bits(),
                        db.sign,
                    );
                    assert_eq!(got, want, "{fa:?}×{fb:?} a={a:#x} b={b:#x}");
                }
            }
        }
    }
}

/// The split sub-table product, recomputed from first principles.
fn split_reference(fmt: Format, a: u64, b: u64) -> FxTerm {
    let da = fmt.decode_reference(a);
    let db = fmt.decode_reference(b);
    FxTerm::product(
        da.sig,
        da.exp,
        fmt.mant_bits(),
        da.sign,
        db.sig,
        db.exp,
        fmt.mant_bits(),
        db.sign,
    )
}

#[test]
fn split_product_coverage_is_exactly_the_16bit_formats() {
    for fmt in Format::ALL {
        let has_split = matches!(fmt, Format::Fp16 | Format::Bf16);
        assert_eq!(tables::product_split(fmt, 0, 0).is_some(), has_split, "{fmt:?}");
    }
}

#[test]
fn split_product_matches_decode_and_multiply_on_boundaries() {
    // Full cross product of the boundary code points: both signs × every
    // exponent field × significand ∈ {zero, min, mid, max}. This sweeps
    // zero, all subnormals' corners, normals, Inf, and the NaN payload
    // extremes — every class transition of the encodings.
    for fmt in [Format::Fp16, Format::Bf16] {
        let mant = fmt.mant_bits();
        let exp_bits = fmt.width() - 1 - mant;
        let sig_max = (1u64 << mant) - 1;
        let mut points = Vec::new();
        for sign in 0..2u64 {
            for e in 0..(1u64 << exp_bits) {
                for sig in [0, 1, sig_max / 2, sig_max] {
                    points.push((sign << (fmt.width() - 1)) | (e << mant) | sig);
                }
            }
        }
        points.dedup();
        for &a in &points {
            for &b in &points {
                let got = tables::product_split(fmt, a, b).expect("16-bit split table");
                assert_eq!(got, split_reference(fmt, a, b), "{fmt:?} a={a:#x} b={b:#x}");
            }
        }
    }
}

#[test]
fn split_product_matches_decode_and_multiply_randomized() {
    // 2^16 random pairs per format (the full 2^32 cross product is out of
    // test-time budget; the boundary sweep above covers the class edges).
    let mut rng = Rng::new(0x5117);
    for fmt in [Format::Fp16, Format::Bf16] {
        for _ in 0..(1 << 16) {
            let a = rng.bits(16);
            let b = rng.bits(16);
            let got = tables::product_split(fmt, a, b).expect("16-bit split table");
            assert_eq!(got, split_reference(fmt, a, b), "{fmt:?} a={a:#x} b={b:#x}");
        }
    }
}
