//! Exhaustive LUT-vs-bit-level equivalence.
//!
//! The table layer (`formats::tables`) must be bitwise invisible: for
//! every format with ≤ 16 storage bits, every one of the 2^width bit
//! patterns must produce identical `Decoded` and `to_f64` results through
//! the LUT dispatch and through the bit-level reference path; and for
//! every ordered pair of ≤ 8-bit formats, the pair-product table must
//! match decode-and-multiply for all pattern pairs — including the
//! NaN/Inf/zero/subnormal code points.

use mma_sim::fixedpoint::FxTerm;
use mma_sim::formats::{tables, Format};

fn narrow(max_width: u32) -> impl Iterator<Item = Format> {
    Format::ALL.iter().copied().filter(move |f| f.width() <= max_width)
}

#[test]
fn lut_coverage_is_exactly_the_narrow_formats() {
    for fmt in Format::ALL {
        let is_narrow = fmt.width() <= 16;
        assert_eq!(tables::decode_lut(fmt).is_some(), is_narrow, "{fmt:?}");
        assert_eq!(tables::f64_lut(fmt).is_some(), is_narrow, "{fmt:?}");
        let has_prod = fmt.width() <= 8;
        assert_eq!(tables::product(fmt, 0, fmt, 0).is_some(), has_prod, "{fmt:?}");
    }
    // the virtual E8M13 target (22 bits) stays on the bit-level path
    assert!(tables::decode_lut(Format::E8M13).is_none());
    assert!(tables::f64_lut(Format::E8M13).is_none());
}

#[test]
fn decode_lut_matches_bit_level_for_every_pattern() {
    for fmt in narrow(16) {
        for bits in 0..=fmt.mask() {
            // `decode` dispatches through the LUT for these formats
            let lut = fmt.decode(bits);
            let reference = fmt.decode_reference(bits);
            assert_eq!(lut, reference, "{fmt:?} bits {bits:#x}");
        }
    }
}

#[test]
fn to_f64_lut_matches_bit_level_for_every_pattern() {
    for fmt in narrow(16) {
        for bits in 0..=fmt.mask() {
            let lut = fmt.to_f64(bits);
            let reference = fmt.to_f64_reference(bits);
            // bit compare: covers NaN payloads and the sign of zero
            assert_eq!(
                lut.to_bits(),
                reference.to_bits(),
                "{fmt:?} bits {bits:#x}: {lut} vs {reference}"
            );
        }
    }
}

#[test]
fn product_lut_matches_decode_and_multiply_for_all_pairs() {
    for fa in narrow(8) {
        for fb in narrow(8) {
            for a in 0..=fa.mask() {
                let da = fa.decode_reference(a);
                for b in 0..=fb.mask() {
                    let db = fb.decode_reference(b);
                    let got = tables::product(fa, a, fb, b).expect("≤8-bit pair has a table");
                    let want = FxTerm::product(
                        da.sig,
                        da.exp,
                        fa.mant_bits(),
                        da.sign,
                        db.sig,
                        db.exp,
                        fb.mant_bits(),
                        db.sign,
                    );
                    assert_eq!(got, want, "{fa:?}×{fb:?} a={a:#x} b={b:#x}");
                }
            }
        }
    }
}
