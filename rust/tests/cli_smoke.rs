//! End-to-end smoke tests of the `mma-sim` binary: every line of output
//! here crosses a real process boundary, so these pin the CLI surface
//! (help/list/simulate) and the JSON-lines seams (`simulate --stdin`,
//! `serve --jsonl`) the cross-process sharding protocol relies on.

use std::io::Write;
use std::process::{Command, Stdio};

use mma_sim::isa::Arch;
use mma_sim::session::{json, SessionBuilder};

fn bin() -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_mma-sim"));
    // keep child batch paths deterministic and cheap on small runners
    cmd.env("MMA_SIM_THREADS", "1");
    cmd
}

fn run_ok(args: &[&str]) -> String {
    let out = bin().args(args).output().expect("spawn mma-sim");
    assert!(
        out.status.success(),
        "mma-sim {args:?} failed: {}\n{}",
        String::from_utf8_lossy(&out.stderr),
        String::from_utf8_lossy(&out.stdout)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

#[test]
fn help_lists_the_subcommands() {
    let text = run_ok(&["help"]);
    for needle in ["USAGE", "simulate", "probe", "serve", "--jsonl", "shard", "Session"] {
        assert!(text.contains(needle), "help missing '{needle}':\n{text}");
    }
}

#[test]
fn list_prints_the_registry() {
    let text = run_ok(&["list"]);
    assert!(text.contains("HMMA.884.F32.F16"), "{text}");
    assert!(text.contains("v_mfma_f32_16x16x4_f32"), "{text}");
    assert!(text.lines().count() > 50, "registry should be substantial");
}

#[test]
fn simulate_reports_outputs_and_reference() {
    let text = run_ok(&["simulate", "--arch", "volta", "--instr", "HMMA.884.F32", "--seed", "1"]);
    assert!(text.contains("instruction: sm70 HMMA.884.F32.F16"), "{text}");
    assert!(text.contains("d[0][0]"), "{text}");
    assert!(text.contains("fp64 ref"), "{text}");
}

#[test]
fn malformed_input_is_a_clean_error_not_a_panic() {
    let out = bin()
        .args(["simulate", "--arch", "volta", "--instr", "HMMA.884"])
        .output()
        .expect("spawn mma-sim");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("ambiguous"), "{err}");
    assert!(!err.contains("panicked"), "{err}");

    let out = bin().args(["simulate", "--arch", "z80"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown architecture"));
}

#[test]
fn simulate_stdin_round_trips_cases_bit_exactly() {
    // The sharding seam: a parent encodes cases, a child executes them.
    let session = SessionBuilder::new()
        .arch(Arch::Volta)
        .instruction("HMMA.884.F32.F16")
        .build()
        .unwrap();
    let cases = [session.random_case(1), session.random_case(2)];

    let mut child = bin()
        .args(["simulate", "--arch", "volta", "--instr", "HMMA.884.F32.F16", "--stdin"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn mma-sim --stdin");
    {
        let stdin = child.stdin.as_mut().expect("child stdin");
        for case in &cases {
            writeln!(stdin, "{}", json::encode_case(case)).unwrap();
        }
        writeln!(stdin, "this is not json").unwrap();
    }
    let out = child.wait_with_output().expect("child output");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "2 outputs + 1 error line:\n{text}");

    for (case, line) in cases.iter().zip(&lines) {
        let got = json::decode_run_output(line).expect("RunOutput line");
        let want = session.run(case).unwrap();
        assert_eq!(got.d.data, want.d.data, "child output must be bit-identical");
    }
    let err = json::JsonValue::parse(lines[2]).unwrap();
    assert!(err.get("error").is_some(), "bad line must yield an error object: {}", lines[2]);
}

#[test]
fn shard_campaign_output_is_byte_identical_across_worker_counts() {
    // the same job list and seed through 1, 2, and 4 shard processes must
    // produce the same bytes: outcome lines in job-id order plus one
    // merged summary (--deterministic zeroes the only timing content)
    let run = |workers: &str| {
        run_ok(&[
            "shard",
            "--workers",
            workers,
            "--jobs",
            "6",
            "--batch",
            "8",
            "--seed",
            "5",
            "--pair",
            "sm70 HMMA.884.F32.F16",
            "--pair",
            "sm70 HMMA.884.F16.F16",
            "--child-workers",
            "2",
            "--deterministic",
        ])
    };
    let one = run("1");
    let two = run("2");
    let four = run("4");
    assert_eq!(one, two, "1 vs 2 shards must merge identically");
    assert_eq!(two, four, "2 vs 4 shards must merge identically");

    let lines: Vec<&str> = one.lines().collect();
    assert_eq!(lines.len(), 7, "6 ordered outcomes + merged summary:\n{one}");
    for (i, line) in lines[..6].iter().enumerate() {
        let v = json::JsonValue::parse(line).unwrap();
        let o = json::outcome_from_json(v.get("outcome").unwrap()).unwrap();
        assert_eq!(o.id, i as u64, "outcome stream must be in job-id order");
        assert_eq!(o.tests, 8);
    }
    let summary = json::JsonValue::parse(lines[6]).unwrap();
    let report = json::report_from_json(summary.get("summary").unwrap()).unwrap();
    assert_eq!(report.total_jobs, 6);
    assert_eq!(report.total_tests, 48);
    assert_eq!(report.total_mismatches, 0, "registry self-pairs are clean");
    assert_eq!(report.wall_micros, 0, "--deterministic zeroes timing");
}

#[test]
fn shard_gemm_cli_is_bit_identical_to_in_process() {
    let text = run_ok(&[
        "shard",
        "--gemm",
        "--arch",
        "turing",
        "--instr",
        "HMMA.1688.F32.F16",
        "--m",
        "32",
        "--n",
        "16",
        "--k",
        "16",
        "--workers",
        "2",
        "--check",
    ]);
    assert!(text.contains("d_digest"), "{text}");
    assert!(text.contains("check ok"), "{text}");
}

#[test]
fn serve_jsonl_executes_jobs_and_summarizes() {
    let mut child = bin()
        .args(["serve", "--jsonl", "--workers", "1"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn mma-sim serve --jsonl");
    {
        let stdin = child.stdin.as_mut().expect("child stdin");
        writeln!(
            stdin,
            "{}",
            r#"{"pair":"sm70 HMMA.884.F32.F16","batch":5,"seed":7}"#
        )
        .unwrap();
        writeln!(stdin, "{}", r#"{"pair":"no-such-pair","batch":5,"seed":7}"#).unwrap();
    }
    let out = child.wait_with_output().expect("child output");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "outcome + error + summary:\n{text}");

    let mut saw_outcome = false;
    let mut saw_error = false;
    let mut saw_summary = false;
    for line in lines {
        let v = json::JsonValue::parse(line).unwrap();
        if let Some(s) = v.get("summary") {
            let report = json::report_from_json(s).unwrap();
            assert_eq!(report.total_tests, 5);
            assert_eq!(report.total_mismatches, 0, "self-verification must be clean");
            saw_summary = true;
        } else if v.get("ok").and_then(|b| b.as_bool()) == Some(true) {
            let o = json::outcome_from_json(v.get("outcome").unwrap()).unwrap();
            assert_eq!(o.tests, 5);
            saw_outcome = true;
        } else {
            assert!(v.get("error").is_some(), "{line}");
            saw_error = true;
        }
    }
    assert!(saw_outcome && saw_error && saw_summary);
}
