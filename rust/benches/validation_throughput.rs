//! Bench: §3.1.4 randomized-validation throughput — the coordinator's
//! end-to-end verification rate (model-vs-model and, when artifacts are
//! built, model-vs-PJRT), across worker counts and batch sizes.

use std::sync::Arc;

use mma_sim::coordinator::{Coordinator, VerifyPair};
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::MmaFormats;
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::runtime::{artifacts_dir, model_for_artifact, read_manifest, Runtime};
use mma_sim::util::{bench, black_box};

fn model() -> MmaModel {
    MmaModel::new(
        "bench",
        (8, 8, 16),
        MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 },
        ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 },
    )
}

fn main() {
    println!("== validation_throughput ==");
    for workers in [1usize, 2, 4, 8] {
        for batch in [50usize, 200] {
            let pair = VerifyPair {
                name: "m".into(),
                dut: Arc::new(model()),
                golden: Arc::new(model()),
            };
            let coord = Coordinator::new(vec![pair], workers, workers * 2);
            let jobs = 8;
            let r = bench(&format!("validate/w{workers}/batch{batch}"), || {
                black_box(coord.run_campaign(jobs, batch, 7));
            });
            println!(
                "    -> {:.0} MMAs verified/s",
                r.throughput((jobs * batch) as f64)
            );
            coord.shutdown();
        }
    }

    // PJRT path (model vs artifact), if built
    let dir = artifacts_dir();
    if dir.join("manifest.txt").exists() {
        let rt = Runtime::new(&dir).expect("runtime");
        if let Some(meta) = read_manifest(&dir)
            .unwrap()
            .into_iter()
            .find(|m| m.name == "hopper_fp16_fp32")
        {
            let pair = VerifyPair {
                name: "pjrt".into(),
                dut: Arc::new(rt.load_mma(&meta).unwrap()),
                golden: Arc::new(model_for_artifact(&meta).unwrap()),
            };
            let coord = Coordinator::new(vec![pair], 1, 2);
            let r = bench("validate/pjrt/hopper_fp16(batch 20)", || {
                black_box(coord.run_campaign(1, 20, 7));
            });
            println!("    -> {:.0} PJRT MMAs verified/s", r.throughput(20.0));
            coord.shutdown();
        }
    } else {
        println!("(artifacts not built; skipping the PJRT leg)");
    }
}
