//! Bench: §3.1.4 randomized-validation throughput — the coordinator's
//! end-to-end verification rate (model-vs-model and, when artifacts are
//! built, model-vs-PJRT), across worker counts and batch sizes.
//!
//! Emits `BENCH_validation_throughput.json` at the repo root
//! (`MMA_BENCH_OUT` overrides the directory). `--smoke` /
//! `MMA_BENCH_SMOKE=1` runs the short CI variant.

use std::sync::Arc;

use mma_sim::coordinator::{Coordinator, VerifyPair};
use mma_sim::formats::{Format, Rho};
use mma_sim::interface::MmaFormats;
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::runtime::{artifacts_dir, model_for_artifact, read_manifest, Runtime};
use mma_sim::util::{bench, black_box};

fn model() -> MmaModel {
    MmaModel::new(
        "bench",
        (8, 8, 16),
        MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 },
        ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 },
    )
}

fn main() {
    mma_sim::util::bench::parse_bench_args();
    println!("== validation_throughput ==");
    let smoke = mma_sim::util::bench::smoke();
    let worker_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let batches: &[usize] = if smoke { &[50] } else { &[50, 200] };
    let mut rows: Vec<(usize, usize, f64)> = Vec::new();
    for &workers in worker_counts {
        for &batch in batches {
            let pair = VerifyPair {
                name: "m".into(),
                dut: Arc::new(model()),
                golden: Arc::new(model()),
            };
            let coord = Coordinator::new(vec![pair], workers, workers * 2);
            let jobs = 8;
            let r = bench(&format!("validate/w{workers}/batch{batch}"), || {
                black_box(coord.run_campaign(jobs, batch, 7).unwrap());
            });
            let rate = r.throughput((jobs * batch) as f64);
            println!("    -> {rate:.0} MMAs verified/s");
            rows.push((workers, batch, rate));
            coord.shutdown();
        }
    }

    // PJRT path (model vs artifact), if built — measured before the JSON
    // record is written so its row is captured too.
    let mut pjrt_rate: Option<f64> = None;
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        println!("(artifacts not built; skipping the PJRT leg)");
    } else {
        match Runtime::new(&dir) {
            Err(e) => println!("skipping PJRT leg: {e}"),
            Ok(rt) => {
                if let Some(meta) = read_manifest(&dir)
                    .unwrap()
                    .into_iter()
                    .find(|m| m.name == "hopper_fp16_fp32")
                {
                    let pair = VerifyPair {
                        name: "pjrt".into(),
                        dut: Arc::new(rt.load_mma(&meta).unwrap()),
                        golden: Arc::new(model_for_artifact(&meta).unwrap()),
                    };
                    let coord = Coordinator::new(vec![pair], 1, 2);
                    let r = bench("validate/pjrt/hopper_fp16(batch 20)", || {
                        black_box(coord.run_campaign(1, 20, 7).unwrap());
                    });
                    let rate = r.throughput(20.0);
                    println!("    -> {rate:.0} PJRT MMAs verified/s");
                    pjrt_rate = Some(rate);
                    coord.shutdown();
                }
            }
        }
    }

    let mut json = String::from("{\n  \"bench\": \"validation_throughput\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    match pjrt_rate {
        Some(rate) => json.push_str(&format!("  \"pjrt_mmas_per_s\": {rate:.1},\n")),
        None => json.push_str("  \"pjrt_mmas_per_s\": null,\n"),
    }
    json.push_str("  \"rows\": [\n");
    for (i, (w, b, rate)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"workers\": {w}, \"batch\": {b}, \"mmas_per_s\": {rate:.1}}}{comma}\n"
        ));
    }
    json.push_str("  ]\n}\n");
    let path = mma_sim::util::bench::out_path("BENCH_validation_throughput.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
