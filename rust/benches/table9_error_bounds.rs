//! Bench: Table 9 — error-bound measurement throughput per model family.

use mma_sim::analysis::error_bounds::{measure, table9};
use mma_sim::isa::{find, Arch};
use mma_sim::util::{bench, black_box};

fn main() {
    println!("== table9_error_bounds ==");
    bench("table9/full(40 samples/model)", || {
        black_box(table9(40));
    });

    for (arch, frag, label) in [
        (Arch::Hopper, "HGMMA.64x8x16.F32.F16", "hopper_fp16"),
        (Arch::Cdna3, "16x16x16_f16", "cdna3_fp16"),
        (Arch::Cdna2, "16x16x16_f16", "cdna2_fp16"),
    ] {
        let instr = find(arch, frag).unwrap();
        bench(&format!("table9/measure/{label}"), || {
            black_box(measure(&instr, 10, 42));
        });
    }

    for row in table9(40) {
        assert!(row.worst_ratio <= 1.0, "{} bound violated", row.instruction);
    }
    println!("table9 bounds verified");
}
