//! Bench: Figure 3 — Monte-Carlo bias experiment throughput (RD vs RZ
//! deviation sampling on the CDNA3 FP16 instruction).

use mma_sim::analysis::bias::{bias_experiment, cdna3_fp16_model};
use mma_sim::clfp::random_inputs;
use mma_sim::interface::MmaInterface;
use mma_sim::util::{bench, black_box, Rng};

fn main() {
    println!("== figure3_bias ==");
    let r = bench("figure3/experiment(8 MMAs = 8192 samples)", || {
        black_box(bias_experiment(8, 1));
    });
    println!(
        "    -> {:.0} deviation samples/s",
        r.throughput(8.0 * 32.0 * 32.0)
    );

    // isolated 32x32x8 MMA on the production model
    let model = cdna3_fp16_model();
    let mut rng = Rng::new(3);
    let (a, b, c) = random_inputs(&mut rng, &model, 0);
    let r = bench("figure3/single_mma_32x32x8", || {
        black_box(model.execute(&a, &b, &c, None));
    });
    println!(
        "    -> {:.0} dot-product-accumulate ops/s",
        r.throughput(32.0 * 32.0)
    );

    let res = bias_experiment(6, 0xF16);
    assert!(res.mean_rd < 0.0 && res.mean_rz.abs() < res.mean_rd.abs() / 4.0);
    println!("figure3 bias direction verified");
}
