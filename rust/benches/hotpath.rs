//! Bench: the simulator hot path — per-elementary-op and per-dot-product
//! throughput for every model family, plus the batch-engine before/after
//! record (seed-style scalar execute vs scratch-reusing serial batch vs
//! multi-threaded parallel batch).
//!
//! Emits `BENCH_hotpath.json` at the repo root (`MMA_BENCH_OUT` overrides
//! the directory); EXPERIMENTS.md records the before/after numbers.
//! `--smoke` (or `MMA_BENCH_SMOKE=1`) runs a seconds-long CI variant whose
//! numbers are not meaningful.

use mma_sim::clfp::random_case_batch;
use mma_sim::fixedpoint::FxTerm;
use mma_sim::formats::{tables, Format, Rho};
use mma_sim::gemm::TiledGemm;
use mma_sim::interface::{auto_threads, parallel_execute_batch_with, MmaInterface};
use mma_sim::interface::{BitMatrix, MmaFormats};
use mma_sim::models::{DpaScratch, MmaModel, ModelSpec};
use mma_sim::ops::{
    e_fdpa, fma, ftz_add, ftz_mul, gtr_fdpa, t_fdpa, tr_fdpa, GtrFdpaCfg, TFdpaCfg, TrFdpaCfg,
};
use mma_sim::util::{bench, black_box, Rng};

fn random_fp16(rng: &mut Rng, n: usize) -> Vec<u64> {
    (0..n).map(|_| rng.bits(16)).collect()
}

/// The PR-1 staged-copy GEMM loop, reproduced as the baseline the
/// zero-copy strided engine is measured against: every tile's A/B/C/D
/// staged through element-wise copy tiles, plus a per-output-column B
/// gather and per-element `dpa` dispatch inside the tile execution.
/// Requires `formats.c == formats.d` (true for the benched tile).
fn staged_gemm(tile: &MmaModel, a: &BitMatrix, b: &BitMatrix, c: &BitMatrix) -> BitMatrix {
    let (tm, tn, tk) = tile.shape();
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    let fmts = tile.formats;
    let mut d = BitMatrix { rows: m, cols: n, fmt: fmts.d, data: c.data.clone() };
    let mut at = BitMatrix::zeros(tm, tk, fmts.a);
    let mut bt = BitMatrix::zeros(tk, tn, fmts.b);
    let mut ct = BitMatrix::zeros(tm, tn, fmts.d);
    let mut out = BitMatrix::zeros(tm, tn, fmts.d);
    let mut bcol = Vec::new();
    for i0 in (0..m).step_by(tm) {
        for j0 in (0..n).step_by(tn) {
            for k0 in (0..k).step_by(tk) {
                for i in 0..tm {
                    for kk in 0..tk {
                        at.set(i, kk, a.get(i0 + i, k0 + kk));
                    }
                }
                for kk in 0..tk {
                    for j in 0..tn {
                        bt.set(kk, j, b.get(k0 + kk, j0 + j));
                    }
                }
                for i in 0..tm {
                    for j in 0..tn {
                        ct.set(i, j, d.get(i0 + i, j0 + j));
                    }
                }
                for j in 0..tn {
                    bt.col_into(j, &mut bcol);
                    for i in 0..tm {
                        out.set(i, j, tile.dpa(at.row(i), &bcol, ct.get(i, j), &[], &[]));
                    }
                }
                for i in 0..tm {
                    for j in 0..tn {
                        d.set(i0 + i, j0 + j, out.get(i, j));
                    }
                }
            }
        }
    }
    d
}

fn main() {
    mma_sim::util::bench::parse_bench_args();
    println!("== hotpath ==");
    let mut rng = Rng::new(0xBEEF);
    let mut records: Vec<(String, f64, f64)> = Vec::new(); // (name, mean_ns, Mdpa/s)

    // elementary ops
    let a16 = random_fp16(&mut rng, 16);
    let b16 = random_fp16(&mut rng, 16);
    let c32 = rng.bits(32);

    let r = bench("op/t_fdpa/L16_F25", || {
        black_box(t_fdpa(
            Format::Fp16,
            &a16,
            &b16,
            c32,
            TFdpaCfg { f: 25, rho: Rho::RzFp32 },
        ));
    });
    println!("    -> {:.2} M t_fdpa/s", r.throughput(1.0) / 1e6);
    records.push((r.name.clone(), r.mean_ns, r.throughput(1.0) / 1e6));

    for r in [
        bench("op/tr_fdpa/L8_F24_F2_31", || {
            black_box(tr_fdpa(Format::Fp16, &a16[..8], &b16[..8], c32, TrFdpaCfg::cdna3()));
        }),
        bench("op/gtr_fdpa/L16", || {
            black_box(gtr_fdpa(Format::Fp8E4M3, &a16, &b16, c32, GtrFdpaCfg::cdna3()));
        }),
        bench("op/e_fdpa/L4", || {
            black_box(e_fdpa(Format::Fp16, &a16[..4], &b16[..4], c32));
        }),
        bench("op/fma_chain/K4", || {
            let mut d = c32;
            for i in 0..4 {
                d = fma(Format::Fp32, a16[i] << 16, b16[i] << 16, d);
            }
            black_box(d);
        }),
        bench("op/ftz_mul+add/P4", || {
            let p0 = ftz_mul(Format::Fp16, a16[0], b16[0]);
            let p1 = ftz_mul(Format::Fp16, a16[1], b16[1]);
            let p2 = ftz_mul(Format::Fp16, a16[2], b16[2]);
            let p3 = ftz_mul(Format::Fp16, a16[3], b16[3]);
            black_box(ftz_add(ftz_add(p0, p1), ftz_add(p2, p3)));
        }),
    ] {
        records.push((r.name.clone(), r.mean_ns, r.throughput(1.0) / 1e6));
    }

    // full-matrix models (the shapes used by validation)
    let fmts = MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 };
    for (label, spec, k) in [
        ("hopper_t_fdpa", ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 }, 16usize),
        ("cdna3_tr_fdpa", ModelSpec::TrFdpa { l_max: 8, f: 24, f2: 31 }, 16),
        ("cdna2_ftz", ModelSpec::FtzAddMul { p: 4 }, 16),
        ("cdna1_e_fdpa", ModelSpec::EFdpa { l: 4 }, 16),
    ] {
        let model = MmaModel::new(label, (16, 8, k), fmts, spec);
        let mut r2 = Rng::new(1);
        let (a, b, c) = mma_sim::clfp::random_inputs(&mut r2, &model, 2);
        let res = bench(&format!("mma/16x8x{k}/{label}"), || {
            black_box(model.execute(&a, &b, &c, None));
        });
        let mdpa = res.throughput((16 * 8) as f64) / 1e6;
        println!("    -> {mdpa:.2} M dpa/s");
        records.push((res.name.clone(), res.mean_ns, mdpa));
    }

    // === batch engine before/after ===========================================
    // "scalar" reproduces the seed execution pattern: one execute() per case
    // with fresh per-call scratch. "batch" reuses one scratch across the
    // whole batch; "parallel" adds scoped worker threads over cases.
    let cases_n = if mma_sim::util::bench::smoke() { 32 } else { 256 };
    let model = MmaModel::new(
        "hopper_t_fdpa",
        (16, 8, 16),
        fmts,
        ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 },
    );
    let mut r3 = Rng::new(0xD06);
    let cases = random_case_batch(&mut r3, &model, cases_n, 0);
    let dpa_per_iter = (cases_n * 16 * 8) as f64;
    let threads = auto_threads(cases_n, 16 * 8 * 16).max(2);

    let r_scalar = bench(&format!("batch/{cases_n}x16x8x16/scalar_execute"), || {
        for cs in &cases {
            black_box(model.execute(&cs.a, &cs.b, &cs.c, None));
        }
    });
    let scalar = r_scalar.throughput(dpa_per_iter) / 1e6;
    println!("    -> {scalar:.2} M dpa/s (seed-style scalar path)");

    let r_serial = bench(&format!("batch/{cases_n}x16x8x16/batch_serial"), || {
        black_box(model.execute_batch(&cases));
    });
    let serial = r_serial.throughput(dpa_per_iter) / 1e6;
    println!("    -> {serial:.2} M dpa/s (scratch-reusing serial batch)");

    let r_par = bench(&format!("batch/{cases_n}x16x8x16/batch_parallel_t{threads}"), || {
        black_box(parallel_execute_batch_with(&model, &cases, threads));
    });
    let parallel = r_par.throughput(dpa_per_iter) / 1e6;
    println!("    -> {parallel:.2} M dpa/s (parallel batch, {threads} threads)");
    println!(
        "    batched multi-threaded speedup vs seed scalar path: {:.2}x",
        parallel / scalar
    );
    for r in [&r_scalar, &r_serial, &r_par] {
        records.push((r.name.clone(), r.mean_ns, r.throughput(dpa_per_iter) / 1e6));
    }

    // === tiled GEMM: staged-copy baseline vs zero-copy strided ===============
    // Framework-shaped GEMM over 16x8x16 tiles (smoke shrinks the outer
    // shape). Both paths run serially so the comparison isolates data
    // movement and dispatch, not thread scheduling: the baseline stages
    // every tile through element-wise copies + per-column gathers (the
    // PR-1 loop), the strided path reads operands in place through views
    // with one B-panel pretranspose per K-chain step. The `gemm` section
    // of BENCH_hotpath.json records the speedup; bench_guard enforces the
    // floor.
    let (gm, gn, gk) = if mma_sim::util::bench::smoke() {
        (64, 64, 64)
    } else {
        (256, 256, 256)
    };
    let gtile = MmaModel::new(
        "gemm_tile",
        (16, 8, 16),
        fmts,
        ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 },
    );
    let ggemm = TiledGemm::from_model(gtile.clone());
    let mut r4 = Rng::new(0x6E44);
    let mut ga = BitMatrix::zeros(gm, gk, fmts.a);
    let mut gb = BitMatrix::zeros(gk, gn, fmts.b);
    let mut gc = BitMatrix::zeros(gm, gn, fmts.c);
    for v in ga.data.iter_mut() {
        *v = fmts.a.from_f64(r4.normal());
    }
    for v in gb.data.iter_mut() {
        *v = fmts.b.from_f64(r4.normal());
    }
    for v in gc.data.iter_mut() {
        *v = fmts.c.from_f64(r4.normal());
    }
    // sanity outside the timed region: the two paths are bit-identical
    assert_eq!(
        staged_gemm(&gtile, &ga, &gb, &gc).data,
        ggemm.execute_with_threads(&ga, &gb, &gc, 1).data,
        "staged and strided GEMM paths must be bit-identical"
    );
    let gemm_dpa = (gm * gn * (gk / 16)) as f64; // one dpa per output per K step
    let shape_label = format!("{gm}x{gn}x{gk}");
    let r_staged = bench(&format!("gemm/{shape_label}/staged_copy"), || {
        black_box(staged_gemm(&gtile, &ga, &gb, &gc));
    });
    let staged = r_staged.throughput(gemm_dpa) / 1e6;
    println!("    -> {staged:.2} M dpa/s (staged-copy baseline)");
    let r_strided = bench(&format!("gemm/{shape_label}/strided"), || {
        black_box(ggemm.execute_with_threads(&ga, &gb, &gc, 1));
    });
    let strided = r_strided.throughput(gemm_dpa) / 1e6;
    println!("    -> {strided:.2} M dpa/s (zero-copy strided)");
    let sp_gemm = strided / staged;
    println!("    strided vs staged-copy speedup: {sp_gemm:.2}x");
    for r in [&r_staged, &r_strided] {
        records.push((r.name.clone(), r.mean_ns, r.throughput(gemm_dpa) / 1e6));
    }

    // === process-level shard seam: marginal overhead vs in-process ===========
    // `mma-sim shard` rides the JSON-lines seam; its fixed cost (child
    // startup, registry + LUT warm) amortizes over a campaign, so the
    // number that must stay bounded is the *marginal* per-job cost vs the
    // in-process coordinator: (t(jobs_hi) - t(jobs_lo)) / (jobs_hi -
    // jobs_lo), best of two runs each. The `shard` section of
    // BENCH_hotpath.json records the ratio; bench_guard enforces the
    // ceiling (GUARD_MAX_SHARD_OVERHEAD overrides).
    let shard_pair = "sm70 HMMA.884.F32.F16";
    let (shard_jobs_lo, shard_jobs_hi) = (8usize, 24usize);
    let shard_batch = if mma_sim::util::bench::smoke() { 100 } else { 400 };
    let inproc_run = |jobs: usize| -> f64 {
        let pairs: Vec<_> = mma_sim::session::registry_pairs(1024)
            .into_iter()
            .filter(|p| p.name == shard_pair)
            .collect();
        assert_eq!(pairs.len(), 1, "shard bench pair must resolve");
        let cfg = mma_sim::session::CampaignConfig {
            workers: 2,
            jobs,
            batch: shard_batch,
            seed: 7,
        };
        let t = std::time::Instant::now();
        black_box(mma_sim::session::campaign(pairs, &cfg).expect("in-process campaign"));
        t.elapsed().as_secs_f64()
    };
    let one_shard_run = |jobs: usize| -> f64 {
        let job_list: Vec<mma_sim::coordinator::Job> = (0..jobs as u64)
            .map(|i| mma_sim::coordinator::Job {
                id: i,
                pair: shard_pair.into(),
                batch: shard_batch,
                seed: 7 + i,
            })
            .collect();
        let cfg = mma_sim::session::ShardConfig {
            workers: 1,
            ..mma_sim::session::ShardConfig::default()
        };
        let transport =
            mma_sim::session::ProcessTransport::with_binary(env!("CARGO_BIN_EXE_mma-sim"));
        let mut sink = std::io::sink();
        let t = std::time::Instant::now();
        black_box(
            mma_sim::session::shard_campaign(job_list, &cfg, &transport, &mut sink)
                .expect("1-shard campaign"),
        );
        t.elapsed().as_secs_f64()
    };
    let best_of_two = |f: &dyn Fn(usize) -> f64, jobs: usize| f(jobs).min(f(jobs));
    let t_in_lo = best_of_two(&inproc_run, shard_jobs_lo);
    let t_in_hi = best_of_two(&inproc_run, shard_jobs_hi);
    let t_sh_lo = best_of_two(&one_shard_run, shard_jobs_lo);
    let t_sh_hi = best_of_two(&one_shard_run, shard_jobs_hi);
    let shard_span = (shard_jobs_hi - shard_jobs_lo) as f64;
    let marg_in = (t_in_hi - t_in_lo) / shard_span;
    let marg_sh = (t_sh_hi - t_sh_lo) / shard_span;
    // A non-positive finite difference means scheduler noise swamped the
    // workload; a ratio built from it would be pure noise (and could
    // hard-fail or silently pass the guard), so report "not measurable"
    // instead — the guard skips with a note rather than judging garbage.
    let shard_overhead =
        if marg_in > 0.0 && marg_sh > 0.0 { Some(marg_sh / marg_in) } else { None };
    match shard_overhead {
        Some(x) => println!(
            "    shard seam: in-process marginal {:.3} ms/job, 1-shard marginal {:.3} \
             ms/job, overhead {x:.2}x",
            marg_in * 1e3,
            marg_sh * 1e3
        ),
        None => println!(
            "    shard seam: marginals below timer resolution (in-process {:.3} ms/job, \
             1-shard {:.3} ms/job) — overhead not measurable this run",
            marg_in * 1e3,
            marg_sh * 1e3
        ),
    }

    // === TCP service tier: cache-hit latency + marginal seam overhead ========
    // Two numbers for the `serve` section of BENCH_hotpath.json, both over
    // a live `serve --tcp` server with real child workers:
    //
    // - cache_hit_speedup: wall time of an identical deterministic job
    //   stream cold (every job computed by the pool) vs warm (every job
    //   answered from the content-addressed cache). The cache exists to
    //   make this ratio large; bench_guard enforces the floor
    //   (GUARD_MIN_CACHE_HIT_SPEEDUP overrides).
    // - overhead_tcp_vs_stdin: marginal per-job cost of the TCP seam vs
    //   the `serve --jsonl` stdin loop, as a finite difference so
    //   connection setup and child startup cancel. Fresh seeds every run
    //   keep the deterministic cache out of this measurement. bench_guard
    //   enforces the ceiling (GUARD_MAX_NET_OVERHEAD overrides).
    let serve_pair = shard_pair;
    let serve_batch = shard_batch;
    let serve_listener =
        std::net::TcpListener::bind("127.0.0.1:0").expect("serve bench: bind ephemeral port");
    let serve_addr = serve_listener.local_addr().expect("serve bench: local addr");
    let serve_cfg = mma_sim::session::NetConfig {
        shard: mma_sim::session::ShardConfig {
            workers: 1,
            ..mma_sim::session::ShardConfig::default()
        },
        queue_depth: 64,
        deterministic: true,
        cache_max: 4096,
        ..mma_sim::session::NetConfig::default()
    };
    let server = std::thread::spawn(move || {
        let transport =
            mma_sim::session::ProcessTransport::with_binary(env!("CARGO_BIN_EXE_mma-sim"));
        mma_sim::session::serve_tcp(serve_listener, &serve_cfg, &transport)
    });
    let make_stream = |seeds: &[u64]| -> String {
        seeds
            .iter()
            .map(|s| format!("{{\"pair\":\"{serve_pair}\",\"batch\":{serve_batch},\"seed\":{s}}}\n"))
            .collect()
    };
    let tcp_round = |input: &str| -> f64 {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(serve_addr).expect("serve bench: connect");
        let t = std::time::Instant::now();
        s.write_all(input.as_bytes()).expect("serve bench: send");
        s.shutdown(std::net::Shutdown::Write).expect("serve bench: half-close");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("serve bench: read replies");
        let dt = t.elapsed().as_secs_f64();
        assert_eq!(
            out.lines().count(),
            input.lines().count() + 1,
            "serve bench: every job must resolve (plus the summary)"
        );
        dt
    };
    // fresh, never-repeating seeds so a run never accidentally warms the
    // cache for a later measurement
    let fresh_seed = std::cell::Cell::new(0x77AA_0000u64);
    let take_seeds = |n: usize| -> Vec<u64> {
        let base = fresh_seed.get();
        fresh_seed.set(base + n as u64);
        (0..n as u64).map(|i| base + i).collect()
    };
    // untimed warmup: children finish registry + LUT warm before timing
    tcp_round(&make_stream(&take_seeds(2)));

    let hit_jobs = 16usize;
    let hit_seeds: Vec<u64> = (0..hit_jobs as u64).map(|i| 0x0011_AA00 + i).collect();
    let hit_stream = make_stream(&hit_seeds);
    let t_cold = tcp_round(&hit_stream);
    let t_warm = tcp_round(&hit_stream).min(tcp_round(&hit_stream));
    let hit_speedup = if t_cold > 0.0 && t_warm > 0.0 { Some(t_cold / t_warm) } else { None };
    match hit_speedup {
        Some(x) => println!(
            "    serve cache: cold {:.3} ms/job, warm {:.3} ms/job, hit speedup {x:.2}x",
            t_cold * 1e3 / hit_jobs as f64,
            t_warm * 1e3 / hit_jobs as f64
        ),
        None => println!("    serve cache: round trips below timer resolution"),
    }

    let stdin_campaign = |jobs: usize| -> f64 {
        use std::io::Write;
        let input = make_stream(&take_seeds(jobs));
        let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_mma-sim"))
            .args(["serve", "--jsonl", "--workers", "2", "--deterministic"])
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("serve bench: spawn serve --jsonl");
        let t = std::time::Instant::now();
        child
            .stdin
            .take()
            .expect("serve bench: child stdin")
            .write_all(input.as_bytes())
            .expect("serve bench: feed jobs");
        let out = child.wait_with_output().expect("serve bench: child output");
        assert!(out.status.success(), "serve bench: stdin loop failed");
        t.elapsed().as_secs_f64()
    };
    let tcp_campaign = |jobs: usize| -> f64 { tcp_round(&make_stream(&take_seeds(jobs))) };
    let (net_jobs_lo, net_jobs_hi) = (8usize, 24usize);
    let best2 = |f: &dyn Fn(usize) -> f64, jobs: usize| f(jobs).min(f(jobs));
    let t_stdin_lo = best2(&stdin_campaign, net_jobs_lo);
    let t_stdin_hi = best2(&stdin_campaign, net_jobs_hi);
    let t_tcp_lo = best2(&tcp_campaign, net_jobs_lo);
    let t_tcp_hi = best2(&tcp_campaign, net_jobs_hi);
    let net_span = (net_jobs_hi - net_jobs_lo) as f64;
    let marg_stdin = (t_stdin_hi - t_stdin_lo) / net_span;
    let marg_tcp = (t_tcp_hi - t_tcp_lo) / net_span;
    // same rule as the shard section: a non-positive finite difference is
    // scheduler noise, not a measurement — report "not measurable" and let
    // the guard skip with a note instead of judging garbage
    let net_overhead =
        if marg_stdin > 0.0 && marg_tcp > 0.0 { Some(marg_tcp / marg_stdin) } else { None };
    match net_overhead {
        Some(x) => println!(
            "    serve seam: stdin marginal {:.3} ms/job, TCP marginal {:.3} ms/job, \
             overhead {x:.2}x",
            marg_stdin * 1e3,
            marg_tcp * 1e3
        ),
        None => println!(
            "    serve seam: marginals below timer resolution (stdin {:.3} ms/job, \
             TCP {:.3} ms/job) — overhead not measurable this run",
            marg_stdin * 1e3,
            marg_tcp * 1e3
        ),
    }
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(serve_addr).expect("serve bench: shutdown");
        s.write_all(b"{\"shutdown\":true}\n").expect("serve bench: shutdown frame");
        s.shutdown(std::net::Shutdown::Write).expect("serve bench: half-close");
        let mut ack = String::new();
        s.read_to_string(&mut ack).expect("serve bench: shutdown ack");
    }
    server
        .join()
        .expect("serve bench: server thread")
        .expect("serve bench: server must exit cleanly");

    // === fleet tier: marginal per-job TCP-transport overhead =================
    // `shard --hosts` rides the same pool as ProcessTransport, swapping
    // the stdin/stdout pipes of a local child for a TCP connection to a
    // `serve --tcp` daemon. The number that must stay bounded is the
    // *marginal* per-job cost vs the local ProcessTransport path (same
    // finite difference as the shard section, so daemon startup and
    // dial cost cancel) — a pure transport-seam ratio: same pool, same
    // merge, same child arithmetic. The `fleet` section of
    // BENCH_hotpath.json records it; bench_guard enforces the ceiling
    // (GUARD_MAX_FLEET_OVERHEAD overrides).
    let fleet_listener =
        std::net::TcpListener::bind("127.0.0.1:0").expect("fleet bench: bind ephemeral port");
    let fleet_addr = fleet_listener.local_addr().expect("fleet bench: local addr");
    let fleet_net_cfg = mma_sim::session::NetConfig {
        shard: mma_sim::session::ShardConfig {
            workers: 1,
            ..mma_sim::session::ShardConfig::default()
        },
        queue_depth: 64,
        // no memoization: the transport seam must be measured, not cached away
        cache_max: 0,
        ..mma_sim::session::NetConfig::default()
    };
    let fleet_server = std::thread::spawn(move || {
        let transport =
            mma_sim::session::ProcessTransport::with_binary(env!("CARGO_BIN_EXE_mma-sim"));
        mma_sim::session::serve_tcp(fleet_listener, &fleet_net_cfg, &transport)
    });
    let fleet_topo = mma_sim::session::FleetTopology::loopback(&[fleet_addr.to_string()]);
    let fleet_transport =
        mma_sim::session::TcpTransport::new(fleet_topo).expect("fleet bench: topology");
    let fleet_run = |jobs: usize| -> f64 {
        let job_list: Vec<mma_sim::coordinator::Job> = take_seeds(jobs)
            .into_iter()
            .enumerate()
            .map(|(i, seed)| mma_sim::coordinator::Job {
                id: i as u64,
                pair: shard_pair.into(),
                batch: shard_batch,
                seed,
            })
            .collect();
        let cfg = mma_sim::session::ShardConfig {
            workers: 1,
            steal: true,
            ..mma_sim::session::ShardConfig::default()
        };
        let mut sink = std::io::sink();
        let t = std::time::Instant::now();
        black_box(
            mma_sim::session::shard_campaign(job_list, &cfg, &fleet_transport, &mut sink)
                .expect("fleet campaign"),
        );
        t.elapsed().as_secs_f64()
    };
    // untimed warmup: the daemon's child finishes registry + LUT warm
    fleet_run(2);
    let t_fl_lo = best2(&fleet_run, shard_jobs_lo);
    let t_fl_hi = best2(&fleet_run, shard_jobs_hi);
    let marg_fleet = (t_fl_hi - t_fl_lo) / shard_span;
    // same rule as the shard/serve sections: a non-positive finite
    // difference is scheduler noise, not a measurement
    let fleet_overhead =
        if marg_sh > 0.0 && marg_fleet > 0.0 { Some(marg_fleet / marg_sh) } else { None };
    match fleet_overhead {
        Some(x) => println!(
            "    fleet seam: process marginal {:.3} ms/job, fleet marginal {:.3} ms/job, \
             overhead {x:.2}x",
            marg_sh * 1e3,
            marg_fleet * 1e3
        ),
        None => println!(
            "    fleet seam: marginals below timer resolution (process {:.3} ms/job, \
             fleet {:.3} ms/job) — overhead not measurable this run",
            marg_sh * 1e3,
            marg_fleet * 1e3
        ),
    }
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(fleet_addr).expect("fleet bench: shutdown");
        s.write_all(b"{\"shutdown\":true}\n").expect("fleet bench: shutdown frame");
        s.shutdown(std::net::Shutdown::Write).expect("fleet bench: half-close");
        let mut ack = String::new();
        s.read_to_string(&mut ack).expect("fleet bench: shutdown ack");
    }
    fleet_server
        .join()
        .expect("fleet bench: server thread")
        .expect("fleet bench: server must exit cleanly");

    // === narrow-format decode & product LUTs =================================
    // Decode-bound and product-bound micro-benchmarks: the bit-level
    // reference path vs the table-driven fast path over identical inputs.
    // The `lut` section of BENCH_hotpath.json records the speedups
    // (target: ≥ 2× on a full run; smoke numbers are noisier).
    // fp16 stream is table-sized (64Ki random patterns) so the LUT is
    // measured under streaming access, not a cache-resident replay of a
    // few indices; the 8-bit tables are tiny, 4Ki inputs suffice.
    let nd16 = 65536usize;
    let nd8 = 4096usize;
    let raw16: Vec<u64> = (0..nd16).map(|_| rng.bits(16)).collect();
    let raw8a: Vec<u64> = (0..nd8).map(|_| rng.bits(8)).collect();
    let raw8b: Vec<u64> = (0..nd8).map(|_| rng.bits(8)).collect();
    tables::warm(Format::Fp16);
    tables::warm(Format::Fp8E4M3);

    let r_dec16_bit = bench("decode/fp16/bitlevel_x65536", || {
        let mut acc = 0u64;
        for &bits in &raw16 {
            acc ^= Format::Fp16.decode_reference(bits).sig;
        }
        black_box(acc);
    });
    let r_dec16_lut = bench("decode/fp16/lut_x65536", || {
        let mut acc = 0u64;
        for &bits in &raw16 {
            acc ^= Format::Fp16.decode(bits).sig;
        }
        black_box(acc);
    });
    let r_dec8_bit = bench("decode/fp8e4m3/bitlevel_x4096", || {
        let mut acc = 0u64;
        for &bits in &raw8a {
            acc ^= Format::Fp8E4M3.decode_reference(bits).sig;
        }
        black_box(acc);
    });
    let r_dec8_lut = bench("decode/fp8e4m3/lut_x4096", || {
        let mut acc = 0u64;
        for &bits in &raw8a {
            acc ^= Format::Fp8E4M3.decode(bits).sig;
        }
        black_box(acc);
    });
    let m8 = Format::Fp8E4M3.mant_bits();
    let r_prod_bit = bench("product/fp8e4m3/bitlevel_x4096", || {
        let mut acc = 0u128;
        for (&x, &y) in raw8a.iter().zip(raw8b.iter()) {
            let dx = Format::Fp8E4M3.decode_reference(x);
            let dy = Format::Fp8E4M3.decode_reference(y);
            acc ^= FxTerm::product(dx.sig, dx.exp, m8, dx.sign, dy.sig, dy.exp, m8, dy.sign).mag;
        }
        black_box(acc);
    });
    let r_prod_lut = bench("product/fp8e4m3/lut_x4096", || {
        let mut acc = 0u128;
        for (&x, &y) in raw8a.iter().zip(raw8b.iter()) {
            acc ^= tables::product(Format::Fp8E4M3, x, Format::Fp8E4M3, y).unwrap().mag;
        }
        black_box(acc);
    });
    let sp_dec16 = r_dec16_bit.mean_ns / r_dec16_lut.mean_ns;
    let sp_dec8 = r_dec8_bit.mean_ns / r_dec8_lut.mean_ns;
    let sp_prod = r_prod_bit.mean_ns / r_prod_lut.mean_ns;
    println!("    decode fp16    LUT speedup: {sp_dec16:.2}x");
    println!("    decode fp8e4m3 LUT speedup: {sp_dec8:.2}x");
    println!("    product fp8e4m3 LUT speedup: {sp_prod:.2}x");
    for r in [&r_dec16_bit, &r_dec16_lut] {
        records.push((r.name.clone(), r.mean_ns, r.throughput(nd16 as f64) / 1e6));
    }
    for r in [&r_dec8_bit, &r_dec8_lut, &r_prod_bit, &r_prod_lut] {
        records.push((r.name.clone(), r.mean_ns, r.throughput(nd8 as f64) / 1e6));
    }

    // === compiled kernels vs interpreter =====================================
    // Headline per-family M dpa/s: one representative registry-shaped model
    // per family through the monomorphized (spec-compiled) kernel and
    // through the retained interpreter — identical traversal, scale
    // gathering, and panel fill; only the per-element run function differs.
    // Bit-identity is asserted outside the timed region (the differential
    // suite covers the full registry; this pins the exact benched shapes).
    // The `compiled` section of BENCH_hotpath.json records both paths and
    // the speedup; bench_guard enforces the in-run floor
    // (GUARD_MIN_COMPILED_SPEEDUP overrides).
    let fam = |f: Format| MmaFormats { a: f, b: f, c: Format::Fp32, d: Format::Fp32 };
    let fam_models = [
        (
            "t",
            MmaModel::new(
                "t/fp16_l16",
                (16, 8, 16),
                fam(Format::Fp16),
                ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 },
            ),
        ),
        (
            "st",
            MmaModel::new(
                "st/fp8e4m3_l32",
                (16, 8, 32),
                fam(Format::Fp8E4M3),
                ModelSpec::StFdpa { l_max: 32, f: 25, rho: Rho::RzFp32, kblock: 32 },
            ),
        ),
        (
            "gst",
            MmaModel::new(
                "gst/fp4_nvf4",
                (16, 8, 64),
                fam(Format::Fp4E2M1),
                ModelSpec::GstFdpa {
                    l: 64,
                    g: 16,
                    f: 35,
                    rho: Rho::RzFp32,
                    kblock: 16,
                    scale_fmt: Format::Ue4M3,
                },
            ),
        ),
        (
            "tr",
            MmaModel::new(
                "tr/fp16_l8",
                (16, 8, 16),
                fam(Format::Fp16),
                ModelSpec::TrFdpa { l_max: 8, f: 24, f2: 31 },
            ),
        ),
        (
            "gtr",
            MmaModel::new(
                "gtr/fp8e4m3_l16",
                (16, 8, 32),
                fam(Format::Fp8E4M3),
                ModelSpec::GtrFdpa { l_max: 16, f: 24, f2: 31 },
            ),
        ),
        (
            "e",
            MmaModel::new("e/fp16_l4", (16, 8, 16), fam(Format::Fp16), ModelSpec::EFdpa { l: 4 }),
        ),
        (
            "ftz",
            MmaModel::new(
                "ftz/fp16_p4",
                (16, 8, 16),
                fam(Format::Fp16),
                ModelSpec::FtzAddMul { p: 4 },
            ),
        ),
        (
            "fma",
            MmaModel::new("fma/fp32", (16, 8, 8), fam(Format::Fp32), ModelSpec::FmaChain),
        ),
    ];
    // (family, shape, compiled M dpa/s, interpreter M dpa/s)
    let mut compiled_rows: Vec<(&str, String, f64, f64)> = Vec::new();
    let mut r5 = Rng::new(0xC04D);
    let mut cscratch = DpaScratch::default();
    for (family, model) in &fam_models {
        assert!(model.is_compiled(), "bench family {family} must route through a compiled kernel");
        let (m, n, k) = model.shape();
        let (ca, cb, cc) = mma_sim::clfp::random_inputs(&mut r5, model, 2);
        let mut d_hot = BitMatrix::zeros(m, n, model.formats.d);
        let mut d_ref = BitMatrix::zeros(m, n, model.formats.d);
        model.execute_into(&ca, &cb, &cc, None, &mut d_hot, &mut cscratch);
        model.execute_reference_into(&ca, &cb, &cc, None, &mut d_ref, &mut cscratch);
        assert_eq!(
            d_hot.data, d_ref.data,
            "compiled/{family}: benched shape must be bit-identical to the interpreter"
        );
        let shape = format!("{m}x{n}x{k}");
        let dpas = (m * n) as f64;
        let r_hot = bench(&format!("compiled/{family}/{shape}/compiled"), || {
            model.execute_into(&ca, &cb, &cc, None, &mut d_hot, &mut cscratch);
            black_box(&d_hot);
        });
        let r_int = bench(&format!("compiled/{family}/{shape}/interpreter"), || {
            model.execute_reference_into(&ca, &cb, &cc, None, &mut d_ref, &mut cscratch);
            black_box(&d_ref);
        });
        let hot = r_hot.throughput(dpas) / 1e6;
        let interp = r_int.throughput(dpas) / 1e6;
        let sp = hot / interp;
        println!("    -> {family}: {hot:.2} vs {interp:.2} M dpa/s ({sp:.2}x compiled/interp)");
        for r in [&r_hot, &r_int] {
            records.push((r.name.clone(), r.mean_ns, r.throughput(dpas) / 1e6));
        }
        compiled_rows.push((family, shape, hot, interp));
    }

    // === JSON record =========================================================
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"hotpath\",\n");
    json.push_str(&format!("  \"smoke\": {},\n", mma_sim::util::bench::smoke()));
    json.push_str(&format!("  \"batch_threads\": {threads},\n"));
    json.push_str("  \"batch\": {\n");
    json.push_str(&format!("    \"cases\": {cases_n},\n"));
    json.push_str("    \"shape\": \"16x8x16\",\n");
    json.push_str(&format!("    \"scalar_mdpa_per_s\": {scalar:.3},\n"));
    json.push_str(&format!("    \"batch_serial_mdpa_per_s\": {serial:.3},\n"));
    json.push_str(&format!("    \"batch_parallel_mdpa_per_s\": {parallel:.3},\n"));
    json.push_str(&format!(
        "    \"speedup_parallel_vs_scalar\": {:.3}\n",
        parallel / scalar
    ));
    json.push_str("  },\n");
    json.push_str("  \"records\": [\n");
    for (i, (name, mean_ns, mdpa)) in records.iter().enumerate() {
        let comma = if i + 1 < records.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"mean_ns\": {mean_ns:.1}, \"m_ops_per_s\": {mdpa:.3}}}{comma}\n"
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"gemm\": {\n");
    json.push_str(&format!("    \"shape\": \"{shape_label}\",\n"));
    json.push_str("    \"tile\": \"16x8x16\",\n");
    json.push_str(&format!("    \"staged_mdpa_per_s\": {staged:.3},\n"));
    json.push_str(&format!("    \"strided_mdpa_per_s\": {strided:.3},\n"));
    json.push_str(&format!("    \"speedup_strided_vs_staged\": {sp_gemm:.3}\n"));
    json.push_str("  },\n");
    json.push_str("  \"shard\": {\n");
    json.push_str(&format!("    \"pair\": \"{shard_pair}\",\n"));
    json.push_str(&format!("    \"jobs_lo\": {shard_jobs_lo},\n"));
    json.push_str(&format!("    \"jobs_hi\": {shard_jobs_hi},\n"));
    json.push_str(&format!("    \"batch\": {shard_batch},\n"));
    json.push_str(&format!(
        "    \"inprocess_marginal_ms_per_job\": {:.4},\n",
        marg_in * 1e3
    ));
    json.push_str(&format!(
        "    \"one_shard_marginal_ms_per_job\": {:.4},\n",
        marg_sh * 1e3
    ));
    match shard_overhead {
        Some(x) => json.push_str(&format!("    \"overhead_marginal_vs_inprocess\": {x:.3},\n")),
        None => json.push_str("    \"overhead_marginal_vs_inprocess\": null,\n"),
    }
    json.push_str(&format!("    \"measurable\": {}\n", shard_overhead.is_some()));
    json.push_str("  },\n");
    json.push_str("  \"serve\": {\n");
    json.push_str(&format!("    \"pair\": \"{serve_pair}\",\n"));
    json.push_str(&format!("    \"batch\": {serve_batch},\n"));
    json.push_str(&format!("    \"hit_jobs\": {hit_jobs},\n"));
    json.push_str(&format!(
        "    \"cold_ms_per_job\": {:.4},\n",
        t_cold * 1e3 / hit_jobs as f64
    ));
    json.push_str(&format!(
        "    \"warm_hit_ms_per_job\": {:.4},\n",
        t_warm * 1e3 / hit_jobs as f64
    ));
    match hit_speedup {
        Some(x) => json.push_str(&format!("    \"cache_hit_speedup\": {x:.3},\n")),
        None => json.push_str("    \"cache_hit_speedup\": null,\n"),
    }
    json.push_str(&format!("    \"jobs_lo\": {net_jobs_lo},\n"));
    json.push_str(&format!("    \"jobs_hi\": {net_jobs_hi},\n"));
    json.push_str(&format!(
        "    \"stdin_marginal_ms_per_job\": {:.4},\n",
        marg_stdin * 1e3
    ));
    json.push_str(&format!(
        "    \"tcp_marginal_ms_per_job\": {:.4},\n",
        marg_tcp * 1e3
    ));
    match net_overhead {
        Some(x) => json.push_str(&format!("    \"overhead_tcp_vs_stdin\": {x:.3},\n")),
        None => json.push_str("    \"overhead_tcp_vs_stdin\": null,\n"),
    }
    json.push_str(&format!("    \"measurable\": {}\n", net_overhead.is_some()));
    json.push_str("  },\n");
    json.push_str("  \"fleet\": {\n");
    json.push_str(&format!("    \"pair\": \"{shard_pair}\",\n"));
    json.push_str(&format!("    \"jobs_lo\": {shard_jobs_lo},\n"));
    json.push_str(&format!("    \"jobs_hi\": {shard_jobs_hi},\n"));
    json.push_str(&format!("    \"batch\": {shard_batch},\n"));
    json.push_str(&format!(
        "    \"process_marginal_ms_per_job\": {:.4},\n",
        marg_sh * 1e3
    ));
    json.push_str(&format!(
        "    \"fleet_marginal_ms_per_job\": {:.4},\n",
        marg_fleet * 1e3
    ));
    match fleet_overhead {
        Some(x) => json.push_str(&format!("    \"overhead_marginal_vs_process\": {x:.3},\n")),
        None => json.push_str("    \"overhead_marginal_vs_process\": null,\n"),
    }
    json.push_str(&format!("    \"measurable\": {}\n", fleet_overhead.is_some()));
    json.push_str("  },\n");
    json.push_str("  \"lut\": {\n");
    json.push_str(&format!("    \"decode_fp16_speedup\": {sp_dec16:.3},\n"));
    json.push_str(&format!("    \"decode_fp8e4m3_speedup\": {sp_dec8:.3},\n"));
    json.push_str(&format!("    \"product_fp8e4m3_speedup\": {sp_prod:.3}\n"));
    json.push_str("  },\n");
    json.push_str("  \"compiled\": {\n");
    for (i, (family, shape, hot, interp)) in compiled_rows.iter().enumerate() {
        let comma = if i + 1 < compiled_rows.len() { "," } else { "" };
        json.push_str(&format!(
            "    \"{family}\": {{\"shape\": \"{shape}\", \"compiled_mdpa_per_s\": {hot:.3}, \
             \"interpreter_mdpa_per_s\": {interp:.3}, \"speedup\": {:.3}}}{comma}\n",
            hot / interp
        ));
    }
    json.push_str("  }\n}\n");

    let path = mma_sim::util::bench::out_path("BENCH_hotpath.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => {
            // a silent write failure would leave the committed placeholder
            // in place and neuter the CI regression guard
            eprintln!("could not write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}
