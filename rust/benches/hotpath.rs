//! Bench: the simulator hot path — per-elementary-op and per-dot-product
//! throughput for every model family. This is the §Perf optimization
//! target (EXPERIMENTS.md records before/after).

use mma_sim::formats::{Format, Rho};
use mma_sim::interface::MmaInterface;
use mma_sim::interface::MmaFormats;
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::ops::{
    e_fdpa, fma, ftz_add, ftz_mul, gtr_fdpa, t_fdpa, tr_fdpa, GtrFdpaCfg, TFdpaCfg, TrFdpaCfg,
};
use mma_sim::util::{bench, black_box, Rng};

fn random_fp16(rng: &mut Rng, n: usize) -> Vec<u64> {
    (0..n).map(|_| rng.bits(16)).collect()
}

fn main() {
    println!("== hotpath ==");
    let mut rng = Rng::new(0xBEEF);

    // elementary ops
    let a16 = random_fp16(&mut rng, 16);
    let b16 = random_fp16(&mut rng, 16);
    let c32 = rng.bits(32);

    let r = bench("op/t_fdpa/L16_F25", || {
        black_box(t_fdpa(
            Format::Fp16,
            &a16,
            &b16,
            c32,
            TFdpaCfg { f: 25, rho: Rho::RzFp32 },
        ));
    });
    println!("    -> {:.2} M t_fdpa/s", r.throughput(1.0) / 1e6);

    bench("op/tr_fdpa/L8_F24_F2_31", || {
        black_box(tr_fdpa(Format::Fp16, &a16[..8], &b16[..8], c32, TrFdpaCfg::cdna3()));
    });
    bench("op/gtr_fdpa/L16", || {
        black_box(gtr_fdpa(Format::Fp8E4M3, &a16, &b16, c32, GtrFdpaCfg::cdna3()));
    });
    bench("op/e_fdpa/L4", || {
        black_box(e_fdpa(Format::Fp16, &a16[..4], &b16[..4], c32));
    });
    bench("op/fma_chain/K4", || {
        let mut d = c32;
        for i in 0..4 {
            d = fma(Format::Fp32, a16[i] << 16, b16[i] << 16, d);
        }
        black_box(d);
    });
    bench("op/ftz_mul+add/P4", || {
        let p0 = ftz_mul(Format::Fp16, a16[0], b16[0]);
        let p1 = ftz_mul(Format::Fp16, a16[1], b16[1]);
        let p2 = ftz_mul(Format::Fp16, a16[2], b16[2]);
        let p3 = ftz_mul(Format::Fp16, a16[3], b16[3]);
        black_box(ftz_add(ftz_add(p0, p1), ftz_add(p2, p3)));
    });

    // full-matrix models (the shapes used by validation)
    let fmts = MmaFormats { a: Format::Fp16, b: Format::Fp16, c: Format::Fp32, d: Format::Fp32 };
    for (label, spec, k) in [
        ("hopper_t_fdpa", ModelSpec::TFdpa { l_max: 16, f: 25, rho: Rho::RzFp32 }, 16usize),
        ("cdna3_tr_fdpa", ModelSpec::TrFdpa { l_max: 8, f: 24, f2: 31 }, 16),
        ("cdna2_ftz", ModelSpec::FtzAddMul { p: 4 }, 16),
        ("cdna1_e_fdpa", ModelSpec::EFdpa { l: 4 }, 16),
    ] {
        let model = MmaModel::new(label, (16, 8, k), fmts, spec);
        let mut r2 = Rng::new(1);
        let (a, b, c) = mma_sim::clfp::random_inputs(&mut r2, &model, 2);
        let res = bench(&format!("mma/16x8x{k}/{label}"), || {
            black_box(model.execute(&a, &b, &c, None));
        });
        println!(
            "    -> {:.2} M dpa/s",
            res.throughput((16 * 8) as f64) / 1e6
        );
    }
}
