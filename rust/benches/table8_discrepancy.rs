//! Bench: Table 8 regeneration — the Eq. 10 discrepancy sweep across the
//! full instruction registry, plus per-architecture timing.

use mma_sim::analysis::discrepancy::{eq10_output, table8};
use mma_sim::isa::{registry, Arch};
use mma_sim::util::{bench, black_box};

fn main() {
    println!("== table8_discrepancy ==");
    bench("table8/full_sweep", || {
        black_box(table8());
    });

    for arch in [Arch::Volta, Arch::Hopper, Arch::Cdna2, Arch::Cdna3] {
        let instrs: Vec<_> = registry().into_iter().filter(|i| i.arch == arch).collect();
        bench(&format!("table8/arch/{}", arch.target()), || {
            for i in &instrs {
                black_box(eq10_output(i));
            }
        });
    }

    // correctness gate: the bench only counts if the table is right
    let rows = table8();
    let hopper = rows.iter().find(|r| r.arch == Arch::Hopper).unwrap();
    assert_eq!(hopper.fp16, Some(-0.75));
    println!("table8 values verified");
}
