//! Bench: cross-architecture consistency analysis — pairwise disagreement
//! rates between all ten architectures on identical random workloads
//! (the quantified version of the paper's reproducibility motivation).

use mma_sim::analysis::consistency::{disagreement_matrix, fp32_all_consistent, render};
use mma_sim::isa::InputClass;
use mma_sim::util::{bench, black_box};

fn main() {
    println!("== consistency ==");
    bench("consistency/fp16_matrix(4 MMAs/pair)", || {
        black_box(disagreement_matrix(InputClass::Fp16, 4, 7));
    });
    bench("consistency/fp32_matrix(4 MMAs/pair)", || {
        black_box(disagreement_matrix(InputClass::Fp32, 4, 7));
    });
    assert!(fp32_all_consistent(4));
    println!("\n{}", render(8));
}
