//! Ablation bench (paper §6.3): accuracy and cost of the software
//! mitigations versus the raw units — the DeepSeek split-K FP32
//! accumulation sweep over the accumulation interval, and the CDNA3
//! zero-C split.

use mma_sim::formats::{Format, Rho};
use mma_sim::interface::{BitMatrix, MmaFormats, MmaInterface};
use mma_sim::mitigations::{CudaCoreAccumulate, ZeroCSplit};
use mma_sim::models::{MmaModel, ModelSpec};
use mma_sim::util::{bench, black_box, Rng};

fn fp8_hopper(k: usize) -> MmaModel {
    MmaModel::new(
        "sm90 QGMMA.F32.E4M3",
        (8, 8, k),
        MmaFormats { a: Format::Fp8E4M3, b: Format::Fp8E4M3, c: Format::Fp32, d: Format::Fp32 },
        ModelSpec::TFdpa { l_max: 32, f: 13, rho: Rho::RzE8M13 },
    )
}

fn mean_rel_err(iface: &dyn MmaInterface, trials: usize, seed: u64) -> f64 {
    let (m, n, k) = iface.shape();
    let mut rng = Rng::new(seed);
    let (mut err, mut cnt) = (0.0f64, 0usize);
    for _ in 0..trials {
        let mut a = BitMatrix::zeros(m, k, Format::Fp8E4M3);
        let mut b = BitMatrix::zeros(k, n, Format::Fp8E4M3);
        let c = BitMatrix::zeros(m, n, Format::Fp32);
        for v in a.data.iter_mut() {
            *v = Format::Fp8E4M3.from_f64(rng.uniform() * 4.0 + 0.25);
        }
        for v in b.data.iter_mut() {
            *v = Format::Fp8E4M3.from_f64(rng.uniform() * 4.0 + 0.25);
        }
        let d = iface.execute(&a, &b, &c, None);
        for i in 0..m {
            for j in 0..n {
                let mut exact = 0.0;
                for kk in 0..k {
                    exact += Format::Fp8E4M3.to_f64(a.get(i, kk))
                        * Format::Fp8E4M3.to_f64(b.get(kk, j));
                }
                let got = Format::Fp32.to_f64(d.get(i, j));
                if exact != 0.0 {
                    err += ((got - exact) / exact).abs();
                    cnt += 1;
                }
            }
        }
    }
    err / cnt.max(1) as f64
}

fn main() {
    println!("== ablation_mitigations ==");
    let k = 32;

    // accuracy sweep: raw vs split-K at intervals 4/8/16
    let raw = fp8_hopper(k);
    println!(
        "accuracy  raw FP8 (F=13):             mean rel err {:.3e}",
        mean_rel_err(&raw, 30, 1)
    );
    for interval in [4usize, 8, 16] {
        let mit = CudaCoreAccumulate::new(fp8_hopper(k), interval);
        println!(
            "accuracy  split-K interval {interval:>2}:        mean rel err {:.3e}",
            mean_rel_err(&mit, 30, 1)
        );
    }

    // cost sweep: the mitigation multiplies MMAU passes
    let mut rng = Rng::new(2);
    let (a, b, c) = mma_sim::clfp::random_inputs(&mut rng, &raw, 0);
    bench("mitigation/raw_fp8_8x8x32", || {
        black_box(raw.execute(&a, &b, &c, None));
    });
    for interval in [4usize, 8, 16] {
        let mit = CudaCoreAccumulate::new(fp8_hopper(k), interval);
        bench(&format!("mitigation/splitk_{interval}_8x8x32"), || {
            black_box(mit.execute(&a, &b, &c, None));
        });
    }

    // CDNA3 zero-C split cost
    let cdna3 = mma_sim::analysis::bias::cdna3_fp16_model();
    let mut rng = Rng::new(3);
    let (a, b, c) = mma_sim::clfp::random_inputs(&mut rng, &cdna3, 0);
    bench("mitigation/cdna3_raw_32x32x8", || {
        black_box(cdna3.execute(&a, &b, &c, None));
    });
    let zc = ZeroCSplit { inner: mma_sim::analysis::bias::cdna3_fp16_model() };
    bench("mitigation/cdna3_zero_c_split_32x32x8", || {
        black_box(zc.execute(&a, &b, &c, None));
    });
    println!("ablation complete");
}
