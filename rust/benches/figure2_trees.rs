//! Bench: Figure 2 — summation-tree signature extraction (CLFP Step 2)
//! for the four exemplar instructions, plus the full probe battery.

use mma_sim::clfp::{probe_battery, run_battery, tree_signature, ProbeBuilder};
use mma_sim::isa::{find, Arch};
use mma_sim::util::{bench, black_box};

fn main() {
    println!("== figure2_trees ==");
    let cases = [
        (Arch::Cdna1, "16x16x4_f32", "fig2a_chain"),
        (Arch::Cdna2, "32x32x8_bf16_1k", "fig2b_pairwise"),
        (Arch::Cdna1, "32x32x4_bf16", "fig2c_nonswamped"),
        (Arch::Volta, "HMMA.884.F32", "fig2d_swamped"),
    ];
    for (arch, frag, label) in cases {
        let model = find(arch, frag).unwrap().model();
        bench(&format!("figure2/signature/{label}"), || {
            black_box(tree_signature(&model));
        });
    }

    let model = find(Arch::Hopper, "HGMMA.64x8x16.F32.F16").unwrap().model();
    let pb = ProbeBuilder::for_interface(&model);
    let battery = probe_battery(&pb);
    bench(&format!("figure2/battery({} probes)/hopper", battery.len()), || {
        black_box(run_battery(&model, &pb, &battery));
    });

    // verify the shapes
    let volta = find(Arch::Volta, "HMMA.884.F32").unwrap().model();
    assert!(tree_signature(&volta).is_swamped_fused());
    // CDNA1 32x32x4 bf16: K=4 chained over L=2 — each node is a
    // non-swamped 3-term fused summation (ratio K-1 within a node),
    // swamping only across the chain
    let cdna1 = find(Arch::Cdna1, "32x32x4_bf16").unwrap().model();
    let sig = tree_signature(&cdna1);
    assert_eq!(sig.ratio[0][1], Some(3), "within-node pair is non-swamped");
    assert_eq!(sig.ratio[2][3], Some(3), "within-node pair is non-swamped");
    assert_eq!(sig.ratio[0][2], Some(1), "cross-node pair swamps the chain");
    println!("figure2 signatures verified");
}
